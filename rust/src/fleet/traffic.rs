//! Traffic model: what a fog shard puts on the air, without PJRT.
//!
//! Every payload size in the system is determined by architecture shapes,
//! quantization widths, and (for object INRs) the bbox size bin — never by
//! the trained weight *values*. That lets the fleet engine build the exact
//! per-record byte stream [`crate::coordinator::FogNode::compress`] would
//! emit by packing zero-weight [`Record`]s: byte totals match the live
//! encoder record-for-record while scaling to thousands of devices with no
//! artifacts or training. JPEG uploads/payloads use the real
//! [`crate::codec::jpeg`] encoder (cheap, session-free).
//!
//! [`ShardTraffic::from_records`] wraps *measured* records instead, which
//! is how `coordinator::sim` adapts its live run onto the fleet engine.

use std::collections::HashMap;

use crate::codec::jpeg;
use crate::config::ArchConfig;
use crate::coordinator::sim::LABEL_BYTES_PER_FRAME;
use crate::coordinator::{EncoderConfig, Method};
use crate::data::Dataset;
use crate::inr::{delta, dequantize, quantize, Bits, QuantWeightSet, Record, WeightSet};
use crate::runtime::names;

use super::cache::blob_hash;
use super::scenario::DeltaConfig;

/// One transmission unit as the fleet engine sees it.
#[derive(Debug, Clone)]
pub struct Blob {
    pub id: usize,
    /// Payload bytes (the paper's size metric, [`Record::payload_size`]).
    pub bytes: u64,
    /// Content hash of the packed record ([`Record::to_bytes`]).
    pub hash: u64,
    /// Adam steps the fog spends producing it (0 for JPEG records).
    pub encode_steps: usize,
    /// Shard-relative index of the last frame this blob needs uploaded
    /// before encoding can start.
    pub ready_after_frame: usize,
    /// Frames covered (sequence length for NeRV records, else 1).
    pub n_frames: u32,
    /// Byte-accounting tag ("inr-broadcast" or "jpeg-direct").
    pub tag: &'static str,
    /// Weight-chain slot for `--delta` (set by
    /// [`ShardTraffic::attach_measured_deltas`]): blobs sharing a slot
    /// are consecutive snapshots of the same template, so the engine can
    /// diff them. The engine falls back to the blob index when absent
    /// (modeled shards, where the blob list itself is the template list).
    pub slot: Option<usize>,
    /// Measured packed size of the residual delta against the previous
    /// snapshot on the same slot ([`crate::inr::delta::encode`] over the
    /// record's dequantized weights). `None` on chain heads and modeled
    /// shards — the engine then prices deltas with
    /// [`crate::fleet::scenario::DeltaConfig::modeled_bytes`].
    pub measured_delta: Option<u64>,
}

/// The full over-the-air footprint of one fog shard.
#[derive(Debug, Clone)]
pub struct ShardTraffic {
    pub method: Method,
    pub n_frames: usize,
    /// Per-frame source→fog JPEG upload sizes (empty for the serverless
    /// JPEG method, which sends straight to receivers).
    pub uploads: Vec<u64>,
    pub blobs: Vec<Blob>,
}

impl ShardTraffic {
    pub fn upload_bytes(&self) -> u64 {
        self.uploads.iter().sum()
    }

    pub fn payload_bytes(&self) -> u64 {
        self.blobs.iter().map(|b| b.bytes).sum()
    }

    /// Label metadata broadcast once per receiver (bbox per frame).
    pub fn label_bytes(&self) -> u64 {
        self.n_frames as u64 * LABEL_BYTES_PER_FRAME
    }

    /// Wrap records measured by a live fog encode (the adapter used by
    /// `coordinator::sim` so its run rides the fleet timeline).
    ///
    /// `ready_after_frame` mirrors `model_shard`'s convention: a record
    /// only becomes encodable once the *last* frame it covers has been
    /// uploaded. Frame-advancing records (JPEG / single / residual /
    /// VideoNet) walk a cursor through the stream; `ObjectPatch` records
    /// ride within the sequence their preceding `VideoNet` just covered.
    pub fn from_records(
        method: Method,
        n_frames: usize,
        uploads: Vec<u64>,
        records: &[Record],
        enc: &EncoderConfig,
    ) -> ShardTraffic {
        let mut cursor = 0usize; // frames covered by the stream so far
        let blobs = records
            .iter()
            .enumerate()
            .map(|(id, rec)| {
                let ready = match rec {
                    Record::ObjectPatch { .. } => cursor.saturating_sub(1),
                    _ => {
                        let adv = match rec {
                            Record::VideoNet { n_frames, .. } => *n_frames as usize,
                            _ => 1,
                        };
                        cursor += adv;
                        cursor.saturating_sub(1)
                    }
                };
                blob_from_record(id, rec, enc, ready.min(n_frames.saturating_sub(1)))
            })
            .collect();
        ShardTraffic { method, n_frames, uploads, blobs }
    }

    /// Measure real residual deltas along the shard's weight chains
    /// (`--delta` over *measured* records, where trained weight values
    /// exist). INR records are grouped by template — same variant and
    /// architecture(s), hence identical tensor shapes — and consecutive
    /// snapshots per template form one chain: the first record's blob
    /// index becomes the shared `slot`, and every later snapshot gets
    /// the packed size of [`crate::inr::delta::encode`] against the
    /// weights its receiver holds (the previous reconstruction), at the
    /// configured width and with the magnitude threshold chosen so
    /// `dc.sparsity` of the residual entries drop. The engine compares
    /// this measured size against the full snapshot per delivery and
    /// keeps whichever is cheaper.
    pub fn attach_measured_deltas(&mut self, records: &[Record], dc: &DeltaConfig) {
        let bits = match dc.bits {
            8 => Bits::B8,
            16 => Bits::B16,
            _ => Bits::F32,
        };
        // template → (slot, weights the receivers currently hold).
        let mut chains: HashMap<String, (usize, WeightSet)> = HashMap::new();
        for (i, rec) in records.iter().enumerate().take(self.blobs.len()) {
            if self.blobs[i].tag != "inr-broadcast" {
                continue;
            }
            let (Some(key), Some(ws)) = (record_template(rec), record_weights(rec)) else {
                continue;
            };
            match chains.get_mut(&key) {
                Some((slot, base)) => {
                    self.blobs[i].slot = Some(*slot);
                    let t = delta::sparsity_threshold(base, &ws, dc.sparsity);
                    if let Ok((d, recon)) = delta::encode(base, &ws, bits, t) {
                        self.blobs[i].measured_delta = Some(d.byte_size() as u64);
                        *base = recon;
                    } else {
                        // Shape drift within a template cannot happen by
                        // construction; keep the chain honest if it does.
                        *base = ws;
                    }
                }
                None => {
                    self.blobs[i].slot = Some(i);
                    chains.insert(key, (i, ws));
                }
            }
        }
    }
}

/// Blob metadata for one packed record.
pub fn blob_from_record(
    id: usize,
    rec: &Record,
    enc: &EncoderConfig,
    ready_after_frame: usize,
) -> Blob {
    let (encode_steps, n_frames, tag) = match rec {
        Record::Jpeg { .. } => (0, 1, "jpeg-direct"),
        Record::SingleImage { .. } => (enc.bg_steps, 1, "inr-broadcast"),
        Record::ResidualImage { .. } => (enc.bg_steps + enc.obj_steps, 1, "inr-broadcast"),
        Record::VideoNet { n_frames, .. } => (enc.nerv_steps, *n_frames, "inr-broadcast"),
        Record::ObjectPatch { .. } => (enc.obj_steps, 1, "inr-broadcast"),
    };
    Blob {
        id,
        bytes: rec.payload_size() as u64,
        hash: blob_hash(&rec.to_bytes()),
        encode_steps,
        ready_after_frame,
        n_frames,
        tag,
        slot: None,
        measured_delta: None,
    }
}

/// Template identity of an INR record: the weight-chain key two records
/// must share for one to be a well-formed residual base of the other
/// (same variant, same architectures ⇒ same tensor shapes and byte
/// size). JPEG records carry no weights and have no template.
fn record_template(rec: &Record) -> Option<String> {
    match rec {
        Record::SingleImage { arch, .. } => Some(format!("single:{arch}")),
        Record::ResidualImage { direct, bg_arch, obj_arch, .. } => {
            Some(format!("residual:{bg_arch}:{obj_arch}:{direct}"))
        }
        Record::VideoNet { arch, n_frames, .. } => Some(format!("video:{arch}:{n_frames}")),
        Record::ObjectPatch { direct, obj_arch, .. } => {
            Some(format!("object:{obj_arch}:{direct}"))
        }
        Record::Jpeg { .. } => None,
    }
}

/// The full trained weight snapshot a record transmits, dequantized to
/// the values a receiver materializes (for `ResidualImage` the
/// background and object sets concatenate — the template fixes both
/// architectures, so shapes line up along any chain).
fn record_weights(rec: &Record) -> Option<WeightSet> {
    match rec {
        Record::SingleImage { weights, .. } | Record::VideoNet { weights, .. } => {
            Some(dequantize(weights))
        }
        Record::ResidualImage { bg, obj, .. } => {
            let mut ws = dequantize(bg);
            ws.tensors.extend(dequantize(obj).tensors);
            Some(ws)
        }
        Record::ObjectPatch { obj, .. } => Some(dequantize(obj)),
        Record::Jpeg { .. } => None,
    }
}

fn zero_qws(shapes: &[(String, Vec<usize>)], bits: Bits) -> QuantWeightSet {
    quantize(&WeightSet::zeros(shapes), bits)
}

/// Model the exact record stream `FogNode::compress(ds, method)` would
/// produce, with zero weights standing in for trained ones (identical
/// sizes). `ids_base` offsets frame/sequence ids so blobs from different
/// shards stay content-distinct.
pub fn model_shard(
    cfg: &ArchConfig,
    ds: &Dataset,
    method: Method,
    enc: &EncoderConfig,
    upload_quality: u8,
    ids_base: u32,
) -> ShardTraffic {
    let mut blobs: Vec<Blob> = Vec::new();
    let mut uploads: Vec<u64> = Vec::new();
    let mut frame_rel = 0usize; // shard-relative frame cursor
    let mut frame_id = ids_base; // record frame ids (content-distinct across shards)

    if !matches!(method, Method::Jpeg { .. }) {
        for (_, _, frame, _) in ds.iter_frames() {
            uploads.push(jpeg::encode(frame, upload_quality).len() as u64);
        }
    }

    // Encode steps and frame span are derived from the record variant by
    // `blob_from_record` — one derivation for modeled and measured shards.
    let push = |rec: Record, ready: usize, blobs: &mut Vec<Blob>| {
        let id = blobs.len();
        blobs.push(blob_from_record(id, &rec, enc, ready));
    };

    for (si, seq) in ds.sequences.iter().enumerate() {
        let profile = cfg.rapid(seq.profile);
        match method {
            Method::Jpeg { quality } => {
                for img in &seq.frames {
                    let rec =
                        Record::Jpeg { frame_id, bytes: jpeg::encode(img, quality) };
                    push(rec, frame_rel, &mut blobs);
                    frame_id += 1;
                    frame_rel += 1;
                }
            }
            Method::RapidSingle => {
                for _ in &seq.frames {
                    let rec = Record::SingleImage {
                        frame_id,
                        arch: names::mlp_key(&profile.baseline),
                        weights: zero_qws(&profile.baseline.param_shapes(), enc.baseline_bits),
                    };
                    push(rec, frame_rel, &mut blobs);
                    frame_id += 1;
                    frame_rel += 1;
                }
            }
            Method::ResRapid { direct } => {
                for (img, bbox) in seq.frames.iter().zip(&seq.boxes) {
                    let padded = bbox.padded(enc.obj_pad, img.width, img.height);
                    let side = padded.w.max(padded.h);
                    let (_, bin) = profile.bin_for_side(side).unwrap_or((
                        profile.object_bins.len() - 1,
                        profile.object_bins.last().expect("nonempty bins"),
                    ));
                    let rec = Record::ResidualImage {
                        frame_id,
                        bbox: padded,
                        direct,
                        bg_arch: names::mlp_key(&profile.background),
                        bg: zero_qws(&profile.background.param_shapes(), enc.bg_bits),
                        obj_arch: names::mlp_key(&bin.arch),
                        obj: zero_qws(&bin.arch.param_shapes(), enc.obj_bits),
                    };
                    push(rec, frame_rel, &mut blobs);
                    frame_id += 1;
                    frame_rel += 1;
                }
            }
            Method::Nerv => {
                let arch = &cfg.nerv_bin(seq.len()).baseline;
                let rec = Record::VideoNet {
                    seq_id: ids_base + si as u32,
                    n_frames: seq.len() as u32,
                    arch: arch.name.clone(),
                    weights: zero_qws(&arch.param_shapes(), enc.baseline_bits),
                };
                let last = frame_rel + seq.len().saturating_sub(1);
                push(rec, last, &mut blobs);
                frame_id += seq.len() as u32;
                frame_rel += seq.len();
            }
            Method::ResNerv => {
                let arch = &cfg.nerv_bin(seq.len()).background;
                let rec = Record::VideoNet {
                    seq_id: ids_base + si as u32,
                    n_frames: seq.len() as u32,
                    arch: arch.name.clone(),
                    weights: zero_qws(&arch.param_shapes(), enc.bg_bits),
                };
                let last = frame_rel + seq.len().saturating_sub(1);
                push(rec, last, &mut blobs);
                for (fi, (img, bbox)) in seq.frames.iter().zip(&seq.boxes).enumerate() {
                    let padded = bbox.padded(enc.obj_pad, img.width, img.height);
                    let side = padded.w.max(padded.h);
                    let (_, bin) = profile.bin_for_side(side).unwrap_or((
                        profile.object_bins.len() - 1,
                        profile.object_bins.last().expect("nonempty bins"),
                    ));
                    let rec = Record::ObjectPatch {
                        frame_id: frame_id + fi as u32,
                        bbox: padded,
                        direct: false,
                        obj_arch: names::mlp_key(&bin.arch),
                        obj: zero_qws(&bin.arch.param_shapes(), enc.obj_bits),
                    };
                    push(rec, last, &mut blobs);
                }
                frame_id += seq.len() as u32;
                frame_rel += seq.len();
            }
        }
    }
    ShardTraffic { method, n_frames: frame_rel, uploads, blobs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_dataset, Profile};

    fn cfg() -> ArchConfig {
        ArchConfig::load_default().unwrap()
    }

    fn small_ds() -> Dataset {
        let mut ds = generate_dataset(Profile::DacSdc, 7, 1);
        ds.sequences[0].frames.truncate(6);
        ds.sequences[0].boxes.truncate(6);
        ds
    }

    #[test]
    fn res_rapid_sizes_are_shape_determined() {
        let cfg = cfg();
        let ds = small_ds();
        let enc = EncoderConfig::fast();
        let t = model_shard(&cfg, &ds, Method::ResRapid { direct: false }, &enc, 95, 0);
        assert_eq!(t.blobs.len(), 6);
        assert_eq!(t.n_frames, 6);
        assert_eq!(t.uploads.len(), 6);
        let profile = cfg.rapid(Profile::DacSdc);
        // 8-bit background: 1 byte/param + 8-byte affine header per tensor.
        let bg_tensors = profile.background.param_shapes().len();
        let bg_bytes = profile.background.param_count() + 8 * bg_tensors;
        for b in &t.blobs {
            assert!(b.bytes as usize > bg_bytes, "blob {} too small", b.id);
            assert_eq!(b.tag, "inr-broadcast");
            assert_eq!(b.encode_steps, enc.bg_steps + enc.obj_steps);
            // Object INR is 16-bit: total = bg + 2*obj_params + headers.
            let obj_bytes = b.bytes as usize - bg_bytes;
            let fits_some_bin = profile.object_bins.iter().any(|bin| {
                obj_bytes == 2 * bin.arch.param_count() + 8 * bin.arch.param_shapes().len()
            });
            assert!(fits_some_bin, "blob {}: obj bytes {obj_bytes} match no bin", b.id);
        }
    }

    #[test]
    fn jpeg_method_has_no_uploads_and_real_jpeg_sizes() {
        let cfg = cfg();
        let ds = small_ds();
        let t = model_shard(&cfg, &ds, Method::Jpeg { quality: 85 }, &EncoderConfig::fast(), 95, 0);
        assert!(t.uploads.is_empty());
        assert_eq!(t.blobs.len(), 6);
        for (b, img) in t.blobs.iter().zip(&ds.sequences[0].frames) {
            let expect = jpeg::encode(img, 85).len() as u64;
            assert_eq!(b.bytes, expect);
            assert_eq!(b.tag, "jpeg-direct");
            assert_eq!(b.encode_steps, 0);
        }
        assert_eq!(t.label_bytes(), 6 * LABEL_BYTES_PER_FRAME);
    }

    #[test]
    fn nerv_emits_one_blob_per_sequence() {
        let cfg = cfg();
        let ds = generate_dataset(Profile::Otb100, 3, 2);
        let enc = EncoderConfig::fast();
        let t = model_shard(&cfg, &ds, Method::Nerv, &enc, 95, 0);
        assert_eq!(t.blobs.len(), 2);
        assert_eq!(t.n_frames, ds.total_frames());
        let t2 = model_shard(&cfg, &ds, Method::ResNerv, &enc, 95, 0);
        assert_eq!(t2.blobs.len(), 2 + ds.total_frames());
        // Background blob only becomes encodable once its sequence is in.
        assert_eq!(t2.blobs[0].ready_after_frame, ds.sequences[0].len() - 1);
    }

    #[test]
    fn blobs_are_content_distinct_within_and_across_shards() {
        let cfg = cfg();
        let ds = small_ds();
        let enc = EncoderConfig::fast();
        let a = model_shard(&cfg, &ds, Method::RapidSingle, &enc, 95, 0);
        let b = model_shard(&cfg, &ds, Method::RapidSingle, &enc, 95, 1_000_000);
        let mut hashes: Vec<u64> =
            a.blobs.iter().chain(&b.blobs).map(|x| x.hash).collect();
        hashes.sort_unstable();
        hashes.dedup();
        assert_eq!(hashes.len(), a.blobs.len() + b.blobs.len());
        // Same shard modeled twice is bit-identical (deterministic).
        let c = model_shard(&cfg, &ds, Method::RapidSingle, &enc, 95, 0);
        for (x, y) in a.blobs.iter().zip(&c.blobs) {
            assert_eq!(x.hash, y.hash);
            assert_eq!(x.bytes, y.bytes);
        }
    }

    #[test]
    fn from_records_waits_for_whole_sequences() {
        // A measured Res-NeRV-like stream: VideoNet(3 frames) + 3 object
        // patches + VideoNet(2 frames). Readiness must track the LAST
        // frame each record covers, matching model_shard's convention.
        let enc = EncoderConfig::fast();
        let qws = crate::inr::quantize(
            &crate::inr::WeightSet::zeros(&[("w".to_string(), vec![4])]),
            Bits::B8,
        );
        let bbox = crate::data::BBox::new(1, 1, 4, 4);
        let patch = |frame_id| Record::ObjectPatch {
            frame_id,
            bbox,
            direct: false,
            obj_arch: "a".into(),
            obj: qws.clone(),
        };
        let recs = vec![
            Record::VideoNet { seq_id: 0, n_frames: 3, arch: "n".into(), weights: qws.clone() },
            patch(0),
            patch(1),
            patch(2),
            Record::VideoNet { seq_id: 1, n_frames: 2, arch: "n".into(), weights: qws.clone() },
        ];
        let t = ShardTraffic::from_records(Method::ResNerv, 5, vec![1; 5], &recs, &enc);
        let ready: Vec<usize> = t.blobs.iter().map(|b| b.ready_after_frame).collect();
        assert_eq!(ready, vec![2, 2, 2, 2, 4]);
    }

    #[test]
    fn attach_measured_deltas_builds_template_chains() {
        use crate::inr::Tensor;
        use crate::util::rng::Pcg32;
        let enc = EncoderConfig::fast();
        let mut rng = Pcg32::seeded(5);
        let base: Vec<f32> = (0..300).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let drift = |rng: &mut Pcg32, data: &[f32]| -> Vec<f32> {
            data.iter().map(|&v| v + rng.range_f32(-0.01, 0.01)).collect()
        };
        let next = drift(&mut rng, &base);
        let next2 = drift(&mut rng, &next);
        let other: Vec<f32> = (0..300).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let ws = |data: Vec<f32>| {
            WeightSet::new(vec![Tensor::new("w".to_string(), vec![300], data)])
        };
        let single = |id: u32, arch: &str, data: Vec<f32>| Record::SingleImage {
            frame_id: id,
            arch: arch.to_string(),
            weights: quantize(&ws(data), Bits::B16),
        };
        let recs = vec![
            single(0, "a", base),
            Record::Jpeg { frame_id: 1, bytes: vec![3; 90] },
            single(2, "a", next),
            single(3, "b", other),
            single(4, "a", next2),
        ];
        let mut t = ShardTraffic::from_records(Method::RapidSingle, 5, vec![], &recs, &enc);
        t.attach_measured_deltas(&recs, &DeltaConfig::default_on());
        // Chain heads carry their slot but no delta; JPEG records carry
        // neither; arch "b" starts its own chain.
        assert_eq!(t.blobs[0].slot, Some(0));
        assert_eq!(t.blobs[0].measured_delta, None);
        assert_eq!(t.blobs[1].slot, None);
        assert_eq!(t.blobs[1].measured_delta, None);
        assert_eq!(t.blobs[3].slot, Some(3));
        assert_eq!(t.blobs[3].measured_delta, None);
        // Successive snapshots of arch "a" share slot 0 and carry a
        // measured residual that beats the full snapshot (a small drift
        // at --delta's 8-bit half-dropped residual must win).
        for i in [2usize, 4] {
            assert_eq!(t.blobs[i].slot, Some(0));
            let md = t.blobs[i].measured_delta.expect("chained snapshot measures a delta");
            assert!(0 < md && md < t.blobs[i].bytes, "blob {i}: delta {md} vs {}", t.blobs[i].bytes);
        }
        // Idempotent shape: re-attaching rebuilds the same chains.
        let again = {
            let mut t2 = ShardTraffic::from_records(Method::RapidSingle, 5, vec![], &recs, &enc);
            t2.attach_measured_deltas(&recs, &DeltaConfig::default_on());
            t2
        };
        for (a, b) in t.blobs.iter().zip(&again.blobs) {
            assert_eq!(a.slot, b.slot);
            assert_eq!(a.measured_delta, b.measured_delta);
        }
    }

    #[test]
    fn from_records_round_trips_payload_sizes() {
        let enc = EncoderConfig::fast();
        let recs = vec![
            Record::Jpeg { frame_id: 0, bytes: vec![9; 123] },
            Record::Jpeg { frame_id: 1, bytes: vec![7; 321] },
        ];
        let t = ShardTraffic::from_records(Method::Jpeg { quality: 85 }, 2, vec![], &recs, &enc);
        assert_eq!(t.payload_bytes(), 444);
        assert_eq!(t.blobs[0].bytes, 123);
        assert_eq!(t.blobs[1].bytes, 321);
        assert_ne!(t.blobs[0].hash, t.blobs[1].hash);
    }
}

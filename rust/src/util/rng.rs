//! Deterministic, seedable PCG-family random number generator.
//!
//! The offline build environment ships no `rand` crate, so the whole
//! repository (dataset synthesis, samplers, property tests, network jitter)
//! runs on this small, fully deterministic PCG32 implementation
//! (O'Neill 2014, `pcg32_random_r` reference constants). Determinism
//! matters: every experiment in EXPERIMENTS.md is reproducible from a seed.

/// PCG32 (XSH-RR variant): 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different stream ids
    /// yield independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        // 24 mantissa-ish bits; exact in f32.
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)` using Lemire's method (unbiased).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u32();
        let mut m = (x as u64).wrapping_mul(n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64).wrapping_mul(n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in `[0, n)`.
    pub fn below_usize(&mut self, n: usize) -> usize {
        assert!(n > 0 && n <= u32::MAX as usize);
        self.below(n as u32) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        if span <= u32::MAX as u64 {
            lo + self.below(span as u32) as i64
        } else {
            lo + (self.next_u64() % span) as i64
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork a derived, independent generator (for per-worker streams).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new(self.next_u64() ^ tag, tag.wrapping_mul(2).wrapping_add(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_enough() {
        let mut r = Pcg32::seeded(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Pcg32::seeded(9);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(13);
        let idx = r.sample_indices(50, 20);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn range_i64_inclusive_bounds() {
        let mut r = Pcg32::seeded(17);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }
}

//! Minimal JSON parser/serializer.
//!
//! The vendored crate set has no `serde`/`serde_json`, and the AOT manifest
//! (`artifacts/manifest.json`) written by `python/compile/aot.py` is JSON,
//! so we implement the subset of JSON we need from scratch: objects, arrays,
//! strings (with escapes), numbers, booleans, null. No streaming, no
//! comments — the manifest is small (KBs).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` if not an object or key missing.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

/// Parse a JSON document. Returns an error with byte position on failure.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            // No surrogate-pair support; manifest is ASCII.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| "invalid utf8 in string")?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    /// Compact serialization (round-trips through `parse`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c\n"}], "d": null}"#).unwrap();
        assert_eq!(j.get("d"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "c\n");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"name":"rapid_decode_bg","params":[[28,2],[28]],"n":12288,"ok":true}"#;
        let j = parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(parse(&printed).unwrap(), j);
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let j = parse("\"\\u0041β\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "Aβ");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}

//! Self-contained utility substrates for the offline build environment:
//! seeded RNG, minimal JSON, CLI parsing, thread pool, and a small
//! property-testing helper. See DESIGN.md "Environment constraints".

pub mod cli;
pub mod json;
pub mod pool;
pub mod propcheck;
pub mod rng;

/// Wall-clock stopwatch used by the pipeline latency accounting.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: std::time::Instant::now() }
    }
    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Format a byte count human-readably (`12.3 KB`, `4.56 MB`).
pub fn fmt_bytes(b: u64) -> String {
    let b = b as f64;
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(1500), "1.50 KB");
        assert_eq!(fmt_bytes(2_500_000), "2.50 MB");
        assert_eq!(fmt_bytes(3_000_000_000), "3.00 GB");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.seconds();
        let b = sw.seconds();
        assert!(b >= a && a >= 0.0);
    }
}

//! A small scoped thread pool.
//!
//! No `tokio`/`rayon` in the vendored crate set, so the parallel-decode path
//! (§3.2 of the paper: images within a group are decoded in parallel on the
//! edge device) runs on this fixed-size worker pool. Jobs are `FnOnce`
//! closures; `scope_run` provides fork-join over borrowed data via
//! `std::thread::scope` for the decode hot path, while `ThreadPool` serves
//! long-lived background work (fog encoder service).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Spawn a pool with `n` workers (clamped to ≥1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("rinr-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    /// Submit a job; returns immediately.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool workers alive");
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fork-join parallel map over items with at most `par` concurrent workers,
/// preserving input order in the output. Uses scoped threads so the closure
/// may borrow from the caller (no `'static` bound) — this is the decode
/// hot-path primitive (one group of same-sized INRs = one `par_map`).
pub fn par_map<T, R, F>(items: &[T], par: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let par = par.max(1).min(items.len().max(1));
    if par <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let out_slots: Vec<Mutex<&mut Option<R>>> =
        out.iter_mut().map(Mutex::new).collect();
    thread::scope(|s| {
        for _ in 0..par {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                **out_slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    drop(out_slots);
    out.into_iter().map(|o| o.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must block until all 10 ran
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_borrows_environment() {
        let base = vec![10u32, 20, 30];
        let items = vec![0usize, 1, 2];
        let out = par_map(&items, 2, |_, &i| base[i] + 1);
        assert_eq!(out, vec![11, 21, 31]);
    }

    #[test]
    fn par_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(par_map(&[5u32], 4, |_, &x| x + 1), vec![6]);
    }
}

//! Tiny command-line argument parser (no `clap` in the vendored set).
//!
//! Supports `subcommand --flag value --switch positional` style. Flags may
//! be given as `--key value` or `--key=value`. Unknown flags are an error so
//! typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` flags, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    /// `known_switches` are boolean flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known_switches: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        // First non-flag token is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&body) {
                    out.switches.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("flag --{body} needs a value"))?;
                    out.flags.insert(body.to_string(), v);
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn parse_env(known_switches: &[&str]) -> Result<Args, String> {
        Self::parse(std::env::args().skip(1), known_switches)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects a number, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_flags_positionals() {
        let a =
            Args::parse(argv("encode --dataset uav --steps 300 out.bin --verbose"), &["verbose"])
                .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("encode"));
        assert_eq!(a.get("dataset"), Some("uav"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 300);
        assert_eq!(a.positional, vec!["out.bin"]);
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(argv("run --alpha=0.12"), &[]).unwrap();
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), 0.12);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv("run --steps"), &[]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(argv("run --steps banana"), &[]).unwrap();
        assert!(a.get_usize("steps", 1).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(argv("run"), &[]).unwrap();
        assert_eq!(a.get_usize("steps", 42).unwrap(), 42);
        assert_eq!(a.get_or("mode", "fog"), "fog");
    }
}

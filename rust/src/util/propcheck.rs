//! Minimal property-based testing helper (no `proptest` in the vendored
//! set). A property is a closure over a seeded RNG that panics on
//! violation; `check` runs it across many seeds and, on failure, reports
//! the failing seed so the case can be replayed deterministically.
//!
//! Used by the coordinator/pipeline/codec test suites for randomized
//! invariants (routing conservation, grouping keys, quantization bounds,
//! JPEG round-trip tolerance, comm-model algebra).

use super::rng::Pcg32;

/// Default number of random cases per property.
pub const DEFAULT_CASES: u64 = 64;

/// Run `prop` for `cases` seeds derived from `base_seed`. The property gets
/// a fresh deterministic RNG per case; any panic is caught, annotated with
/// the seed, and re-raised.
pub fn check_seeded<F: Fn(&mut Pcg32) + std::panic::RefUnwindSafe>(
    name: &str,
    base_seed: u64,
    cases: u64,
    prop: F,
) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg32::seeded(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed on case {case} (replay seed {seed}): {msg}"
            );
        }
    }
}

/// Run a property with the default case count.
pub fn check<F: Fn(&mut Pcg32) + std::panic::RefUnwindSafe>(name: &str, prop: F) {
    check_seeded(name, 0xC0FFEE, DEFAULT_CASES, prop)
}

/// Generate a random `Vec<f32>` with values in `[lo, hi)`.
pub fn vec_f32(rng: &mut Pcg32, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| rng.range_f32(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", |rng| {
            let a = rng.f32();
            let b = rng.f32();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        // Silence the default panic-hook spam from the inner catch_unwind.
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = std::panic::catch_unwind(|| {
            check("always-fails", |rng| {
                assert!(rng.f32() < 0.0, "cannot hold");
            });
        });
        std::panic::set_hook(hook);
        if let Err(p) = r {
            std::panic::resume_unwind(p);
        }
    }

    #[test]
    fn vec_f32_bounds() {
        check("vec-bounds", |rng| {
            let v = vec_f32(rng, 100, -2.0, 3.0);
            assert_eq!(v.len(), 100);
            assert!(v.iter().all(|x| (-2.0..3.0).contains(x)));
        });
    }
}

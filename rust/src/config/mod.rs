//! Configuration system: loads `configs/arch.json` (checked in at the
//! repo root and shared with `python/compile/aot.py`) into typed
//! architecture tables, plus runtime knobs (network bandwidth, training
//! hyper-parameters) with defaults matching the paper's experiment
//! settings (§5.1). The architecture tables are also the size oracle of
//! the [`crate::fleet`] traffic model: INR payload bytes are fully
//! determined by `param_shapes()` and the quantization widths, which is
//! what lets the fleet engine reproduce live byte totals without PJRT.

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

use crate::data::Profile;
use crate::inr::arch::{MlpArch, NervArch, ObjectBin};
use crate::util::json::{parse, Json};

/// Rapid-INR architecture set for one dataset profile (Table 1 analogue).
#[derive(Debug, Clone)]
pub struct RapidProfile {
    pub background: MlpArch,
    pub baseline: MlpArch,
    pub object_bins: Vec<ObjectBin>,
}

impl RapidProfile {
    /// The size bin an object with padded bbox `side = max(w, h)` falls in.
    pub fn bin_for_side(&self, side: usize) -> Option<(usize, &ObjectBin)> {
        self.object_bins
            .iter()
            .enumerate()
            .find(|(_, b)| side <= b.max_side)
    }
}

/// NeRV sequence-length bin (Table 2 analogue: sized by video length).
#[derive(Debug, Clone)]
pub struct NervBin {
    pub max_frames: usize,
    pub background: NervArch,
    pub baseline: NervArch,
}

/// Full architecture configuration.
#[derive(Debug, Clone)]
pub struct ArchConfig {
    pub frame_w: usize,
    pub frame_h: usize,
    /// Frames per NeRV decode call (fixed HLO batch).
    pub nerv_decode_batch: usize,
    /// Pixel rows in Rapid train/decode artifacts (= frame_w · frame_h).
    pub train_pixel_batch: usize,
    pub detect: DetectConfig,
    rapid: Vec<(Profile, RapidProfile)>,
    pub nerv_archs: Vec<NervArch>,
    pub nerv_bins: Vec<NervBin>,
}

/// TinyDet backbone configuration (YOLOv8 stand-in).
#[derive(Debug, Clone, Copy)]
pub struct DetectConfig {
    pub batch: usize,
    pub base_channels: usize,
    pub stages: usize,
    pub head_hidden: usize,
}

impl ArchConfig {
    /// Load from a JSON file (normally `configs/arch.json`).
    pub fn load(path: &Path) -> Result<ArchConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json_text(&text)
    }

    /// Locate `configs/arch.json` relative to the repo root (walks up from
    /// the current directory — benches/examples run from different cwds).
    pub fn load_default() -> Result<ArchConfig> {
        let path = find_repo_file("configs/arch.json")?;
        Self::load(&path)
    }

    pub fn from_json_text(text: &str) -> Result<ArchConfig> {
        let j = parse(text).map_err(|e| anyhow!("arch.json: {e}"))?;
        let frame = j.get("frame").ok_or_else(|| anyhow!("missing frame"))?;
        let frame_w = frame.get("width").and_then(Json::as_usize).unwrap_or(128);
        let frame_h = frame.get("height").and_then(Json::as_usize).unwrap_or(96);

        let det = j.get("detect").ok_or_else(|| anyhow!("missing detect"))?;
        let detect = DetectConfig {
            batch: det.get("batch").and_then(Json::as_usize).unwrap_or(8),
            base_channels: det.get("base_channels").and_then(Json::as_usize).unwrap_or(16),
            stages: det.get("stages").and_then(Json::as_usize).unwrap_or(3),
            head_hidden: det.get("head_hidden").and_then(Json::as_usize).unwrap_or(64),
        };

        let mut rapid = Vec::new();
        let rj = j.get("rapid").ok_or_else(|| anyhow!("missing rapid"))?;
        for p in Profile::ALL {
            let pj = rj
                .get(p.name())
                .ok_or_else(|| anyhow!("missing rapid profile {}", p.name()))?;
            let background =
                MlpArch::from_json(&format!("{}_bg", p.name()), pj.get("background").unwrap())
                    .ok_or_else(|| anyhow!("bad background arch for {}", p.name()))?;
            let baseline =
                MlpArch::from_json(&format!("{}_base", p.name()), pj.get("baseline").unwrap())
                    .ok_or_else(|| anyhow!("bad baseline arch for {}", p.name()))?;
            let mut object_bins = Vec::new();
            for (i, bj) in pj
                .get("object_bins")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing object_bins"))?
                .iter()
                .enumerate()
            {
                let max_side = bj
                    .get("max_side")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("bad max_side"))?;
                let arch = MlpArch::from_json(
                    &format!("{}_obj{}", p.name(), i),
                    bj.get("arch").ok_or_else(|| anyhow!("missing bin arch"))?,
                )
                .ok_or_else(|| anyhow!("bad bin arch"))?;
                object_bins.push(ObjectBin { max_side, arch });
            }
            if !object_bins.windows(2).all(|w| w[0].max_side < w[1].max_side) {
                bail!("object bins must have increasing max_side");
            }
            rapid.push((p, RapidProfile { background, baseline, object_bins }));
        }

        let nj = j.get("nerv").ok_or_else(|| anyhow!("missing nerv"))?;
        let mut nerv_archs = Vec::new();
        for name in [
            "background_small",
            "background_medium",
            "background_large",
            "baseline_small",
            "baseline_medium",
            "baseline_large",
        ] {
            let aj = nj.get(name).ok_or_else(|| anyhow!("missing nerv arch {name}"))?;
            nerv_archs.push(
                NervArch::from_json(name, aj).ok_or_else(|| anyhow!("bad nerv arch {name}"))?,
            );
        }
        let mut nerv_bins = Vec::new();
        for bj in nj
            .get("sequence_bins")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing sequence_bins"))?
        {
            let max_frames = bj
                .get("max_frames")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("bad max_frames"))?;
            let bg_name = bj.get("background").and_then(Json::as_str).unwrap_or_default();
            let base_name = bj.get("baseline").and_then(Json::as_str).unwrap_or_default();
            let find = |n: &str| -> Result<NervArch> {
                nerv_archs
                    .iter()
                    .find(|a| a.name == n)
                    .cloned()
                    .ok_or_else(|| anyhow!("unknown nerv arch {n}"))
            };
            nerv_bins.push(NervBin {
                max_frames,
                background: find(bg_name)?,
                baseline: find(base_name)?,
            });
        }

        Ok(ArchConfig {
            frame_w,
            frame_h,
            nerv_decode_batch: j.get("nerv_decode_batch").and_then(Json::as_usize).unwrap_or(4),
            train_pixel_batch: j
                .get("train_pixel_batch")
                .and_then(Json::as_usize)
                .unwrap_or(frame_w * frame_h),
            detect,
            rapid,
            nerv_archs,
            nerv_bins,
        })
    }

    pub fn rapid(&self, p: Profile) -> &RapidProfile {
        &self.rapid.iter().find(|(q, _)| *q == p).expect("profile present").1
    }

    /// NeRV bin for a sequence of `n_frames` (falls back to the largest).
    pub fn nerv_bin(&self, n_frames: usize) -> &NervBin {
        self.nerv_bins
            .iter()
            .find(|b| n_frames <= b.max_frames)
            .unwrap_or_else(|| self.nerv_bins.last().expect("nonempty nerv bins"))
    }

    /// Ordered TinyDet parameter shapes (mirror of
    /// `model.detect_param_shapes`): `stages` stride-2 convs from RGB,
    /// channel-doubling, then a two-layer head over the flattened map.
    pub fn detect_param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let d = &self.detect;
        let mut shapes = Vec::new();
        let mut cin = 3usize;
        let mut c = d.base_channels;
        for i in 0..d.stages {
            shapes.push((format!("conv{i}_w"), vec![3, 3, cin, c]));
            shapes.push((format!("conv{i}_b"), vec![c]));
            cin = c;
            c *= 2;
        }
        let ds = 1usize << d.stages;
        let fh = self.frame_h.div_ceil(ds);
        let fw = self.frame_w.div_ceil(ds);
        shapes.push(("head_w1".to_string(), vec![fh * fw * cin, d.head_hidden]));
        shapes.push(("head_b1".to_string(), vec![d.head_hidden]));
        shapes.push(("head_w2".to_string(), vec![d.head_hidden, 5]));
        shapes.push(("head_b2".to_string(), vec![5]));
        shapes
    }

    /// All distinct Rapid MLP archs (for artifact enumeration).
    pub fn all_mlp_archs(&self) -> Vec<&MlpArch> {
        let mut out: Vec<&MlpArch> = Vec::new();
        for (_, rp) in &self.rapid {
            out.push(&rp.background);
            out.push(&rp.baseline);
            for b in &rp.object_bins {
                out.push(&b.arch);
            }
        }
        out
    }
}

/// Walk up from cwd looking for `rel`; also honors `RESIDUAL_INR_ROOT`.
pub fn find_repo_file(rel: &str) -> Result<PathBuf> {
    if let Ok(root) = std::env::var("RESIDUAL_INR_ROOT") {
        let p = Path::new(&root).join(rel);
        if p.exists() {
            return Ok(p);
        }
    }
    let mut dir = std::env::current_dir()?;
    loop {
        let candidate = dir.join(rel);
        if candidate.exists() {
            return Ok(candidate);
        }
        if !dir.pop() {
            bail!("could not locate {rel} above the current directory (set RESIDUAL_INR_ROOT)");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_repo_config() {
        let cfg = ArchConfig::load_default().unwrap();
        assert_eq!(cfg.frame_w, 128);
        assert_eq!(cfg.frame_h, 96);
        for p in Profile::ALL {
            let rp = cfg.rapid(p);
            // Table 1 ordering: background strictly smaller than baseline.
            assert!(rp.background.param_count() < rp.baseline.param_count());
            // Object INRs are tiny (≤ ~15% of the baseline).
            for b in &rp.object_bins {
                assert!(b.arch.param_count() * 4 < rp.baseline.param_count());
            }
            assert_eq!(rp.object_bins.len(), 4);
        }
        assert_eq!(cfg.nerv_bins.len(), 3);
        for b in &cfg.nerv_bins {
            // Table 2 ordering: background NeRV smaller than same-bin baseline.
            assert!(b.background.param_count() < b.baseline.param_count());
            assert_eq!(b.background.frame_w(), cfg.frame_w);
            assert_eq!(b.background.frame_h(), cfg.frame_h);
        }
    }

    #[test]
    fn bin_selection() {
        let cfg = ArchConfig::load_default().unwrap();
        let rp = cfg.rapid(Profile::Uav123);
        let (i0, b0) = rp.bin_for_side(10).unwrap();
        assert_eq!(i0, 0);
        assert!(b0.max_side >= 10);
        let (i3, _) = rp.bin_for_side(30).unwrap();
        assert_eq!(i3, 3);
        assert!(rp.bin_for_side(100).is_none());
        // NeRV bins by sequence length.
        assert_eq!(cfg.nerv_bin(20).max_frames, 32);
        assert_eq!(cfg.nerv_bin(40).max_frames, 48);
        assert_eq!(cfg.nerv_bin(64).max_frames, 64);
        assert_eq!(cfg.nerv_bin(1000).max_frames, 64); // clamps to largest
    }

    #[test]
    fn rejects_malformed_config() {
        assert!(ArchConfig::from_json_text("{}").is_err());
        assert!(ArchConfig::from_json_text("not json").is_err());
    }
}

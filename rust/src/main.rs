//! `residual-inr` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//! * `simulate`  — run the end-to-end fog on-device-learning experiment
//! * `fleet`     — discrete-event multi-fog scale-out simulation
//! * `compress`  — compress a synthetic dataset, report size/PSNR
//! * `commmodel` — evaluate the §4 analytical communication model
//! * `info`      — artifact/config inventory
//!
//! Examples:
//! ```text
//! residual-inr simulate --method res-rapid --profile uav123 --epochs 2
//! residual-inr fleet --scenario paper-10 --method res-rapid
//! residual-inr fleet --scenario sharded --fogs 4 --edges 200
//! residual-inr compress --method jpeg --quality 60
//! residual-inr commmodel --devices 10 --alpha 0.15
//! ```

use anyhow::{anyhow, Result};

use residual_inr::config::ArchConfig;
use residual_inr::coordinator::{run_sim, EncoderConfig, Method, SimConfig};
use residual_inr::data::Profile;
use residual_inr::fleet::FleetConfig;
use residual_inr::util::cli::Args;
use residual_inr::util::fmt_bytes;

fn parse_method(s: &str, quality: u8) -> Result<Method> {
    Ok(match s {
        "jpeg" => Method::Jpeg { quality },
        "rapid" | "rapid-inr" => Method::RapidSingle,
        "res-rapid" | "res-rapid-inr" => Method::ResRapid { direct: false },
        "res-rapid-direct" => Method::ResRapid { direct: true },
        "nerv" => Method::Nerv,
        "res-nerv" => Method::ResNerv,
        _ => {
            return Err(anyhow!(
                "unknown method {s} (jpeg|rapid|res-rapid|res-rapid-direct|nerv|res-nerv)"
            ))
        }
    })
}

fn main() -> Result<()> {
    let args = Args::parse_env(&["no-grouping", "full"]).map_err(|e| anyhow!(e))?;
    match args.subcommand.as_deref() {
        Some("simulate") => simulate(&args),
        Some("fleet") => fleet(&args),
        Some("compress") => compress(&args),
        Some("commmodel") => commmodel(&args),
        Some("info") => info(),
        _ => {
            println!(
                "residual-inr — fog on-device learning via implicit neural representations\n\
                 \n\
                 USAGE: residual-inr <simulate|fleet|compress|commmodel|info> [flags]\n\
                 \n\
                 simulate   --method <jpeg|rapid|res-rapid|res-rapid-direct|nerv|res-nerv>\n\
                 \u{20}          --profile <dac-sdc|uav123|otb100>\n\
                 \u{20}          --sequences N --epochs N --receivers N --max-frames N [--no-grouping]\n\
                 fleet      --scenario <paper-10|sharded|hierarchical> --method M --profile P\n\
                 \u{20}          --fogs N --edges N --workers K --sequences N --max-frames N\n\
                 \u{20}          --epochs N --seed S --cache-mb MB (paper-10 = 1 fog, 10 edge\n\
                 \u{20}          devices; sharded = per-fog shards over mesh backhaul;\n\
                 \u{20}          hierarchical = cloud→fog→edge relay with weight caching)\n\
                 compress   --method M --profile P --max-frames N [--quality Q]\n\
                 commmodel  --devices K --alpha A [--receivers N]\n\
                 info\n\
                 \n\
                 See examples/ for scripted end-to-end runs."
            );
            Ok(())
        }
    }
}

fn simulate(args: &Args) -> Result<()> {
    let cfg = ArchConfig::load_default()?;
    let quality = args.get_usize("quality", 85).map_err(|e| anyhow!(e))? as u8;
    let method = parse_method(args.get_or("method", "res-rapid"), quality)?;
    let profile = Profile::from_name(args.get_or("profile", "dac-sdc"))
        .ok_or_else(|| anyhow!("unknown profile"))?;
    let mut sim = SimConfig::small(method);
    sim.profile = profile;
    sim.grouped = !args.has("no-grouping");
    sim.n_sequences = args.get_usize("sequences", 4).map_err(|e| anyhow!(e))?;
    sim.epochs = args.get_usize("epochs", 2).map_err(|e| anyhow!(e))?;
    sim.n_receivers = args.get_usize("receivers", 1).map_err(|e| anyhow!(e))?;
    sim.pretrain_steps = args.get_usize("pretrain", 120).map_err(|e| anyhow!(e))?;
    sim.seed = args.get_u64("seed", 7).map_err(|e| anyhow!(e))?;
    sim.max_train_frames = Some(args.get_usize("max-frames", 24).map_err(|e| anyhow!(e))?);
    if args.has("full") {
        sim.enc = EncoderConfig::default();
        sim.max_train_frames = None;
    }
    println!(
        "# simulate method={} profile={} grouped={}",
        sim.method.name(),
        profile.name(),
        sim.grouped
    );
    let r = run_sim(&cfg, &sim)?;
    println!("frames trained           : {}", r.n_train_frames);
    println!("avg frame payload        : {}", fmt_bytes(r.avg_frame_bytes as u64));
    println!("upload bytes             : {}", fmt_bytes(r.upload_bytes));
    println!("broadcast bytes          : {}", fmt_bytes(r.broadcast_bytes));
    println!("total network bytes      : {}", fmt_bytes(r.total_bytes));
    println!("transmission time        : {:.2} s", r.transmission_seconds);
    println!("decode time              : {:.2} s", r.decode_seconds);
    println!("train time               : {:.2} s", r.train_seconds);
    println!("edge end-to-end          : {:.2} s", r.edge_total_seconds());
    println!("fog encode time          : {:.2} s (off critical path)", r.fog_encode_seconds);
    println!("device memory            : {}", fmt_bytes(r.device_memory_bytes as u64));
    println!("fleet makespan (overlap) : {:.2} s", r.fleet_makespan_seconds);
    println!("mAP50-95 before → after  : {:.3} → {:.3}", r.map_before, r.map_after);
    println!("mean IoU after           : {:.3}", r.mean_iou_after);
    Ok(())
}

fn fleet(args: &Args) -> Result<()> {
    let cfg = ArchConfig::load_default()?;
    let quality = args.get_usize("quality", 85).map_err(|e| anyhow!(e))? as u8;
    let method = parse_method(args.get_or("method", "res-rapid"), quality)?;
    let mut fc = FleetConfig::from_scenario(args.get_or("scenario", "paper-10"), method)?;
    if let Some(p) = args.get("profile") {
        fc.profile = Profile::from_name(p).ok_or_else(|| anyhow!("unknown profile"))?;
    }
    fc.n_fogs = args.get_usize("fogs", fc.n_fogs).map_err(|e| anyhow!(e))?;
    fc.n_edges = args.get_usize("edges", fc.n_edges).map_err(|e| anyhow!(e))?;
    fc.encode_workers =
        args.get_usize("workers", fc.encode_workers).map_err(|e| anyhow!(e))?;
    fc.n_sequences = args.get_usize("sequences", fc.n_sequences).map_err(|e| anyhow!(e))?;
    fc.epochs = args.get_usize("epochs", fc.epochs).map_err(|e| anyhow!(e))?;
    fc.seed = args.get_u64("seed", fc.seed).map_err(|e| anyhow!(e))?;
    let max = args
        .get_usize("max-frames", fc.max_frames.unwrap_or(24))
        .map_err(|e| anyhow!(e))?;
    fc.max_frames = if max == 0 { None } else { Some(max) };
    let cache_mb = args.get_usize("cache-mb", 64).map_err(|e| anyhow!(e))?;
    fc.cache_bytes = (cache_mb as u64) << 20;
    fc.bandwidth = args.get_f64("bandwidth", fc.bandwidth).map_err(|e| anyhow!(e))?;
    // Keep the wired-backhaul-faster-than-cell invariant when only the
    // cell bandwidth is overridden.
    fc.backhaul_bandwidth = fc.bandwidth * residual_inr::fleet::scenario::BACKHAUL_FACTOR;
    fc.backhaul_bandwidth =
        args.get_f64("backhaul", fc.backhaul_bandwidth).map_err(|e| anyhow!(e))?;
    let report = residual_inr::fleet::run(&cfg, &fc)?;
    report.print();
    Ok(())
}

fn compress(args: &Args) -> Result<()> {
    use residual_inr::coordinator::FogNode;
    use residual_inr::data::generate_dataset;
    use residual_inr::runtime::Session;
    let cfg = ArchConfig::load_default()?;
    let quality = args.get_usize("quality", 85).map_err(|e| anyhow!(e))? as u8;
    let method = parse_method(args.get_or("method", "res-rapid"), quality)?;
    let profile = Profile::from_name(args.get_or("profile", "dac-sdc"))
        .ok_or_else(|| anyhow!("unknown profile"))?;
    let max = args.get_usize("max-frames", 8).map_err(|e| anyhow!(e))?;
    let session = Session::open_default()?;
    let fog = FogNode::new(&session, &cfg, EncoderConfig::fast());
    let mut ds = generate_dataset(profile, args.get_u64("seed", 7).map_err(|e| anyhow!(e))?, 1);
    ds.sequences[0].frames.truncate(max);
    ds.sequences[0].boxes.truncate(max);
    let c = fog.compress(&ds, method)?;
    println!("method            : {}", c.method.name());
    println!("frames            : {}", c.n_frames);
    println!("records           : {}", c.records.len());
    println!("payload           : {}", fmt_bytes(c.payload_bytes as u64));
    println!("avg frame payload : {}", fmt_bytes(c.avg_frame_bytes() as u64));
    println!("encode time       : {:.2} s ({} Adam steps)", c.encode_seconds, c.encode_steps);
    Ok(())
}

fn commmodel(args: &Args) -> Result<()> {
    use residual_inr::commmodel as cm;
    let k = args.get_usize("devices", 10).map_err(|e| anyhow!(e))?;
    let alpha = args.get_f64("alpha", 0.15).map_err(|e| anyhow!(e))?;
    let m = 1e6;
    let s = cm::serverless_total(&cm::uniform_all_to_all(k, m, false));
    let f = cm::fog_total(&cm::uniform_all_to_all(k, m, true), alpha);
    println!("k = {k} devices, α = {alpha}, m = 1 MB/device, all-to-all");
    println!("serverless D_s = {}", fmt_bytes(s as u64));
    println!("fog        D_f = {}", fmt_bytes(f as u64));
    println!("reduction      = {:.2}x", s / f);
    match cm::min_receivers_for_fog(alpha) {
        Some(n) => println!("fog beneficial from n_i >= {n} receivers (n_i > 1/(1-a))"),
        None => println!("fog never beneficial at a >= 1"),
    }
    Ok(())
}

fn info() -> Result<()> {
    use residual_inr::runtime::Manifest;
    let cfg = ArchConfig::load_default()?;
    let m = Manifest::load_default()?;
    println!("frame: {}x{}", cfg.frame_w, cfg.frame_h);
    println!("artifacts: {}", m.entries.len());
    for p in Profile::ALL {
        let rp = cfg.rapid(p);
        println!(
            "{:8} bg {}x{} ({} params)  baseline {}x{} ({} params)  obj bins: {}",
            p.name(),
            rp.background.layers,
            rp.background.hidden,
            rp.background.param_count(),
            rp.baseline.layers,
            rp.baseline.hidden,
            rp.baseline.param_count(),
            rp.object_bins
                .iter()
                .map(|b| format!("{}x{}@{}", b.arch.layers, b.arch.hidden, b.max_side))
                .collect::<Vec<_>>()
                .join(" "),
        );
    }
    Ok(())
}

//! `residual-inr` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//! * `simulate` (`sim`) — run the end-to-end fog on-device-learning
//!   experiment; `--fogs F --topology sharded|hierarchical` shards the
//!   measured pipeline across F live-encoded fog cells
//! * `fleet`     — discrete-event multi-fog scale-out simulation
//! * `compress`  — compress a synthetic dataset, report size/PSNR
//! * `commmodel` — evaluate the §4 analytical communication model
//! * `info`      — artifact/config inventory
//!
//! Examples:
//! ```text
//! residual-inr simulate --method res-rapid --profile uav123 --epochs 2
//! residual-inr sim --fogs 4 --topology sharded --method res-rapid
//! residual-inr sim --fogs 2 --backend native --method res-rapid
//! residual-inr fleet --scenario paper-10 --method res-rapid
//! residual-inr fleet --scenario sharded --fogs 4 --edges 200 --cost analytical
//! residual-inr compress --method jpeg --quality 60
//! residual-inr commmodel --devices 10 --alpha 0.15
//! ```

use anyhow::{anyhow, Result};

use residual_inr::config::ArchConfig;
use residual_inr::coordinator::{
    run_multi, run_sim, EncoderConfig, Method, MultiFogConfig, SimConfig,
};
use residual_inr::costmodel::{self, Analytical, Calibrated, CostModel, CostSource};
use residual_inr::data::Profile;
use residual_inr::fleet::scenario::parse_churn;
use residual_inr::fleet::{
    CellSimMode, DeltaConfig, FleetConfig, JoinSpec, RebroadcastPolicy, Topology,
};
use residual_inr::runtime::{BackendKind, SessionSpec};
use residual_inr::util::cli::Args;
use residual_inr::util::fmt_bytes;

/// Parse `--backend auto|native|pjrt` into a session spec. `auto` (the
/// default) picks PJRT when `artifacts/` exists and the pure-Rust native
/// SIMD engine otherwise; `pjrt` errors without artifacts; `native` never
/// needs them.
fn parse_backend(args: &Args) -> Result<SessionSpec> {
    SessionSpec::resolve(BackendKind::parse(args.get_or("backend", "auto"))?)
}

fn parse_policy(args: &Args) -> Result<RebroadcastPolicy> {
    let s = args.get_or("policy", "unicast");
    RebroadcastPolicy::from_name(s).ok_or_else(|| {
        anyhow!("unknown policy {s} (unicast|cell-multicast|multicast-tree|receiver-pull|auto)")
    })
}

/// Parse the lossy-link / churn knobs shared by `fleet` and `sim`:
/// `--loss` (cell reception loss), `--backhaul-loss` (defaults to 0 —
/// wired links are clean unless said otherwise), `--churn` (join
/// times, see [`parse_churn`]).
fn parse_link_args(args: &Args, n_fogs: usize) -> Result<(f64, f64, Vec<JoinSpec>)> {
    let loss = args.get_f64("loss", 0.0).map_err(|e| anyhow!(e))?;
    let backhaul_loss = args.get_f64("backhaul-loss", 0.0).map_err(|e| anyhow!(e))?;
    let joins = match args.get("churn") {
        Some(spec) => parse_churn(spec, n_fogs)?,
        None => Vec::new(),
    };
    Ok((loss, backhaul_loss, joins))
}

/// Parse the scale-engine knobs shared by `fleet` and `sim --fogs`:
/// `--cell-mode exact|aggregate|auto[:threshold]` (aggregate cell
/// rounds) and `--threads N` (windowed parallel executor; 0 =
/// sequential).
fn parse_engine_args(args: &Args) -> Result<(CellSimMode, usize)> {
    let mode = CellSimMode::from_name(args.get_or("cell-mode", "auto")).map_err(|e| anyhow!(e))?;
    let threads = args.get_usize("threads", 0).map_err(|e| anyhow!(e))?;
    Ok((mode, threads))
}

/// Parse the residual-delta knobs shared by `fleet` and `sim --fogs`:
/// `--delta` turns delta redistribution on, `--delta-bits 8|16|32` and
/// `--delta-sparsity T` tune the residual quantization width and the
/// dropped fraction (defaults 8 bits, 0.5; `validate()` bounds both).
fn parse_delta(args: &Args) -> Result<Option<DeltaConfig>> {
    if !args.has("delta") {
        for flag in ["delta-bits", "delta-sparsity"] {
            if args.get(flag).is_some() {
                return Err(anyhow!("--{flag} requires --delta"));
            }
        }
        return Ok(None);
    }
    let mut dc = DeltaConfig::default_on();
    dc.bits = args.get_usize("delta-bits", dc.bits as usize).map_err(|e| anyhow!(e))? as u32;
    dc.sparsity = args.get_f64("delta-sparsity", dc.sparsity).map_err(|e| anyhow!(e))?;
    Ok(Some(dc))
}

fn parse_method(s: &str, quality: u8) -> Result<Method> {
    Ok(match s {
        "jpeg" => Method::Jpeg { quality },
        "rapid" | "rapid-inr" => Method::RapidSingle,
        "res-rapid" | "res-rapid-inr" => Method::ResRapid { direct: false },
        "res-rapid-direct" => Method::ResRapid { direct: true },
        "nerv" => Method::Nerv,
        "res-nerv" => Method::ResNerv,
        _ => {
            return Err(anyhow!(
                "unknown method {s} (jpeg|rapid|res-rapid|res-rapid-direct|nerv|res-nerv)"
            ))
        }
    })
}

fn main() -> Result<()> {
    let args = Args::parse_env(&["no-grouping", "full", "delta"]).map_err(|e| anyhow!(e))?;
    match args.subcommand.as_deref() {
        Some("simulate") | Some("sim") => simulate(&args),
        Some("fleet") => fleet(&args),
        Some("compress") => compress(&args),
        Some("commmodel") => commmodel(&args),
        Some("info") => info(),
        _ => {
            println!(
                "residual-inr — fog on-device learning via implicit neural representations\n\
                 \n\
                 USAGE: residual-inr <simulate|fleet|compress|commmodel|info> [flags]\n\
                 \n\
                 simulate   --method <jpeg|rapid|res-rapid|res-rapid-direct|nerv|res-nerv>\n\
                 \u{20}          --profile <dac-sdc|uav123|otb100>\n\
                 \u{20}          --backend <auto|native|pjrt>\n\
                 \u{20}          --sequences N --epochs N --receivers N --max-frames N [--no-grouping]\n\
                 \u{20}          --fogs F --topology <sharded|hierarchical> --policy P\n\
                 \u{20}          --loss P --churn T1,T2,.. --cell-mode M --threads N\n\
                 \u{20}          --encode-workers N [--delta [--delta-bits N --delta-sparsity T]]\n\
                 \u{20}          (--backend picks the compute engine: pjrt runs the AOT\n\
                 \u{20}          artifacts through XLA, native runs the pure-Rust SIMD\n\
                 \u{20}          kernels with no artifacts at all, auto = pjrt when\n\
                 \u{20}          artifacts/ exists else native — every run stays fully\n\
                 \u{20}          measured either way.\n\
                 \u{20}          F > 1 runs the live encoder per fog shard and reports\n\
                 \u{20}          fleet-wide makespan from a cost model calibrated on the\n\
                 \u{20}          run; --encode-workers N encodes shards on N threads, one\n\
                 \u{20}          session each, default min(shards, cores) — byte\n\
                 \u{20}          totals identical for any N; --delta diffs the real\n\
                 \u{20}          trained weights per template chain and skips any\n\
                 \u{20}          residual that packs larger than full; alias: sim)\n\
                 fleet      --scenario <paper-10|sharded|hierarchical> --method M --profile P\n\
                 \u{20}          --fogs N --edges N --workers K --sequences N --max-frames N\n\
                 \u{20}          --epochs N --seed S --cache-mb MB --cost <auto|analytical|calibrated>\n\
                 \u{20}          --backend <auto|native|pjrt> (calibration session)\n\
                 \u{20}          --policy <unicast|cell-multicast|multicast-tree|receiver-pull|auto>\n\
                 \u{20}          --loss P --backhaul-loss P --churn T1,T2,..\n\
                 \u{20}          --cell-mode <exact|aggregate|auto[:threshold]> --threads N\n\
                 \u{20}          --arrivals <poisson:RATE|diurnal:RATE,PERIOD> --horizon S\n\
                 \u{20}          --deadline S[,shed] --handover F>G:T,.. --fail F:T --depart F:T,..\n\
                 \u{20}          [--delta [--delta-bits <8|16|32> --delta-sparsity T]]\n\
                 \u{20}          (paper-10 = 1 fog, 10 edge devices; sharded = per-fog shards\n\
                 \u{20}          over mesh backhaul; hierarchical = cloud→fog→edge relay;\n\
                 \u{20}          unicast = legacy byte-parity default, the others share one\n\
                 \u{20}          airtime per cell and dedup or tree-push the backhaul;\n\
                 \u{20}          auto picks unicast-vs-multicast per blob from cell\n\
                 \u{20}          population, blob size and loss rate.\n\
                 \u{20}          --loss P drops each cell reception with probability P:\n\
                 \u{20}          unicast legs repair by stop-and-wait ARQ, multicast legs\n\
                 \u{20}          by 64 B NACKs + shared re-airs, pull legs by re-request;\n\
                 \u{20}          repair/control bytes are reported apart, so delivered\n\
                 \u{20}          totals stay loss-invariant. --churn T1,T2 adds receivers\n\
                 \u{20}          joining at those times [fog:T pins a fog], served catch-up\n\
                 \u{20}          from the fog cache.\n\
                 \u{20}          --cell-mode aggregate collapses each (blob, cell) round\n\
                 \u{20}          into one closed-form macro event — byte-identical at loss\n\
                 \u{20}          0, O(1) events per cell — enabling 10^6-edge fleets; auto\n\
                 \u{20}          switches at a population threshold (default 4096).\n\
                 \u{20}          --threads N runs per-fog event loops on N workers under a\n\
                 \u{20}          conservative lookahead window, bit-identical for any N.\n\
                 \u{20}          --arrivals + --horizon stream frames continuously per fog\n\
                 \u{20}          (seeded Poisson or day/night diurnal process) instead of\n\
                 \u{20}          one t=0 batch; the report adds p50/p99 delivery staleness,\n\
                 \u{20}          drop rate and stream goodput. --deadline S counts\n\
                 \u{20}          deliveries staler than S as misses; --deadline S,shed also\n\
                 \u{20}          drops frames on arrival whose estimated staleness already\n\
                 \u{20}          misses S (admission control, counted as drops).\n\
                 \u{20}          --handover F>G:T moves\n\
                 \u{20}          a receiver between cells mid-run; --fail F:T kills fog F at\n\
                 \u{20}          T and re-attaches its receivers to the cheapest survivor;\n\
                 \u{20}          --depart F:T removes a receiver from fog F at T — a\n\
                 \u{20}          handover with no destination cell and no catch-up leg.\n\
                 \u{20}          --delta ships a quantized sparse residual instead of the\n\
                 \u{20}          full snapshot whenever the destination provably holds the\n\
                 \u{20}          chain's previous snapshot (falls back to full — and counts\n\
                 \u{20}          it — on churn, failure or cache eviction); --delta-bits\n\
                 \u{20}          sets the residual width, --delta-sparsity the dropped\n\
                 \u{20}          fraction. Off by default: byte-identical to the pre-delta\n\
                 \u{20}          engine on every policy and topology)\n\
                 compress   --method M --profile P --max-frames N [--quality Q] --backend B\n\
                 commmodel  --devices K --alpha A [--receivers N]\n\
                 info\n\
                 \n\
                 See examples/ for scripted end-to-end runs."
            );
            Ok(())
        }
    }
}

fn simulate(args: &Args) -> Result<()> {
    let cfg = ArchConfig::load_default()?;
    let quality = args.get_usize("quality", 85).map_err(|e| anyhow!(e))? as u8;
    let method = parse_method(args.get_or("method", "res-rapid"), quality)?;
    let profile = Profile::from_name(args.get_or("profile", "dac-sdc"))
        .ok_or_else(|| anyhow!("unknown profile"))?;
    let mut sim = SimConfig::small(method);
    sim.backend = parse_backend(args)?;
    sim.profile = profile;
    sim.grouped = !args.has("no-grouping");
    sim.n_sequences = args.get_usize("sequences", 4).map_err(|e| anyhow!(e))?;
    sim.epochs = args.get_usize("epochs", 2).map_err(|e| anyhow!(e))?;
    sim.n_receivers = args.get_usize("receivers", 1).map_err(|e| anyhow!(e))?;
    sim.pretrain_steps = args.get_usize("pretrain", 120).map_err(|e| anyhow!(e))?;
    sim.seed = args.get_u64("seed", 7).map_err(|e| anyhow!(e))?;
    sim.max_train_frames = Some(args.get_usize("max-frames", 24).map_err(|e| anyhow!(e))?);
    if args.has("full") {
        sim.enc = EncoderConfig::default();
        sim.max_train_frames = None;
    }
    let fogs = args.get_usize("fogs", 1).map_err(|e| anyhow!(e))?;
    if fogs <= 1 && args.get("topology").is_some() {
        return Err(anyhow!("--topology requires --fogs > 1 (the multi-fog measured pipeline)"));
    }
    for flag in ["policy", "loss", "churn", "cell-mode", "threads"] {
        if fogs <= 1 && args.get(flag).is_some() {
            return Err(anyhow!(
                "--{flag} requires --fogs > 1 (use `fleet --{flag}` for synthetic runs)"
            ));
        }
    }
    if fogs <= 1
        && (args.has("delta")
            || args.get("delta-bits").is_some()
            || args.get("delta-sparsity").is_some())
    {
        return Err(anyhow!(
            "--delta requires --fogs > 1 (use `fleet --delta` for synthetic runs)"
        ));
    }
    if fogs <= 1 && args.get("encode-workers").is_some() {
        return Err(anyhow!(
            "--encode-workers requires --fogs > 1 (the parallel multi-shard encode)"
        ));
    }
    for flag in ["arrivals", "horizon", "deadline", "handover", "fail", "depart"] {
        if args.get(flag).is_some() {
            return Err(anyhow!(
                "sim runs the live encoder over a finite batch; streaming workloads are \
                 fleet-only (use `fleet --{flag}`)"
            ));
        }
    }
    if args.get("backhaul-loss").is_some() {
        return Err(anyhow!(
            "sim applies --loss to cells and backhaul alike; use `fleet --backhaul-loss` \
             for split rates"
        ));
    }
    if fogs > 1 {
        let topology = args.get_or("topology", "sharded");
        let topology = Topology::from_name(topology)
            .ok_or_else(|| anyhow!("unknown topology {topology} (sharded|hierarchical)"))?;
        let policy = parse_policy(args)?;
        let (loss, _backhaul_loss, joins) = parse_link_args(args, fogs)?;
        let (cell_sim, threads) = parse_engine_args(args)?;
        let encode_workers = args.get_usize("encode-workers", 0).map_err(|e| anyhow!(e))?;
        let delta = parse_delta(args)?;
        let mf = MultiFogConfig {
            n_fogs: fogs,
            topology,
            policy,
            loss,
            joins,
            cell_sim,
            threads,
            encode_workers,
            delta,
        };
        println!(
            "# simulate method={} profile={} fogs={} topology={} policy={} loss={} churn={} \
             backend={}",
            sim.method.name(),
            profile.name(),
            fogs,
            topology.name(),
            policy.name(),
            mf.loss,
            mf.joins.len(),
            sim.backend.backend_name()
        );
        // The live encoder runs on either backend: PJRT over the AOT
        // artifacts when present, the native SIMD engine otherwise — the
        // measured pipeline never degrades to modeled shards.
        let r = run_multi(&cfg, &sim, &mf)?;
        r.print();
        return Ok(());
    }
    println!(
        "# simulate method={} profile={} grouped={} backend={}",
        sim.method.name(),
        profile.name(),
        sim.grouped,
        sim.backend.backend_name()
    );
    let r = run_sim(&cfg, &sim)?;
    println!("frames trained           : {}", r.n_train_frames);
    println!("avg frame payload        : {}", fmt_bytes(r.avg_frame_bytes as u64));
    println!("upload bytes             : {}", fmt_bytes(r.upload_bytes));
    println!("broadcast bytes          : {}", fmt_bytes(r.broadcast_bytes));
    println!("total network bytes      : {}", fmt_bytes(r.total_bytes));
    println!("transmission time        : {:.2} s", r.transmission_seconds);
    println!("decode time              : {:.2} s", r.decode_seconds);
    println!("train time               : {:.2} s", r.train_seconds);
    println!("edge end-to-end          : {:.2} s", r.edge_total_seconds());
    println!("fog encode time          : {:.2} s (off critical path)", r.fog_encode_seconds);
    println!("device memory            : {}", fmt_bytes(r.device_memory_bytes as u64));
    println!(
        "fleet makespan (overlap) : {:.2} s ({} cost model, parity mismatch {} B)",
        r.fleet_makespan_seconds,
        r.costs.source.name(),
        r.byte_parity_mismatch
    );
    println!("mAP50-95 before → after  : {:.3} → {:.3}", r.map_before, r.map_after);
    println!("mean IoU after           : {:.3}", r.mean_iou_after);
    Ok(())
}

fn fleet(args: &Args) -> Result<()> {
    let cfg = ArchConfig::load_default()?;
    let quality = args.get_usize("quality", 85).map_err(|e| anyhow!(e))? as u8;
    let method = parse_method(args.get_or("method", "res-rapid"), quality)?;
    let profile = Profile::from_name(args.get_or("profile", "dac-sdc"))
        .ok_or_else(|| anyhow!("unknown profile"))?;
    // Virtual-time prices: measured against a live session (PJRT or the
    // native engine per --backend) unless forced analytical via --cost.
    let enc = EncoderConfig::fast();
    let costs = match args.get_or("cost", "auto") {
        "analytical" => Analytical::new(&cfg, profile, method, &enc).book(),
        "calibrated" => {
            let session = parse_backend(args)?.open()?;
            Calibrated::probe(&session, &cfg, profile, method, &enc)?.book()
        }
        "auto" => costmodel::auto(&parse_backend(args)?, &cfg, profile, method, &enc),
        other => return Err(anyhow!("unknown --cost {other} (auto|analytical|calibrated)")),
    };
    if costs.source == CostSource::Analytical {
        println!(
            "# cost model: analytical (--cost analytical, or the calibration probe \
             failed — see stderr)"
        );
    }
    let mut fc = FleetConfig::from_scenario(args.get_or("scenario", "paper-10"), method, costs)?;
    fc.policy = parse_policy(args)?;
    fc.profile = profile;
    fc.n_fogs = args.get_usize("fogs", fc.n_fogs).map_err(|e| anyhow!(e))?;
    fc.n_edges = args.get_usize("edges", fc.n_edges).map_err(|e| anyhow!(e))?;
    fc.encode_workers =
        args.get_usize("workers", fc.encode_workers).map_err(|e| anyhow!(e))?;
    fc.n_sequences = args.get_usize("sequences", fc.n_sequences).map_err(|e| anyhow!(e))?;
    fc.epochs = args.get_usize("epochs", fc.epochs).map_err(|e| anyhow!(e))?;
    fc.seed = args.get_u64("seed", fc.seed).map_err(|e| anyhow!(e))?;
    let max = args
        .get_usize("max-frames", fc.max_frames.unwrap_or(24))
        .map_err(|e| anyhow!(e))?;
    fc.max_frames = if max == 0 { None } else { Some(max) };
    let cache_mb = args.get_usize("cache-mb", 64).map_err(|e| anyhow!(e))?;
    fc.cache_bytes = (cache_mb as u64) << 20;
    fc.bandwidth = args.get_f64("bandwidth", fc.bandwidth).map_err(|e| anyhow!(e))?;
    // Keep the wired-backhaul-faster-than-cell invariant when only the
    // cell bandwidth is overridden.
    fc.backhaul_bandwidth = fc.bandwidth * residual_inr::fleet::scenario::BACKHAUL_FACTOR;
    fc.backhaul_bandwidth =
        args.get_f64("backhaul", fc.backhaul_bandwidth).map_err(|e| anyhow!(e))?;
    let (loss, backhaul_loss, joins) = parse_link_args(args, fc.n_fogs)?;
    fc.loss_cell = loss;
    fc.loss_backhaul = backhaul_loss;
    fc.joins = joins;
    let (cell_sim, threads) = parse_engine_args(args)?;
    fc.cell_sim = cell_sim;
    fc.threads = threads;
    fc.delta = parse_delta(args)?;
    // Streaming knobs: --arrivals + --horizon switch the run from one
    // finite batch to a steady-state stream; --deadline, --handover,
    // --fail and --depart ride on top (validate() enforces the
    // dependencies).
    match (args.get("arrivals"), args.get("horizon")) {
        (Some(spec), Some(_)) => {
            let (deadline, shed) = match args.get("deadline") {
                Some(d) => {
                    let (secs, shed) =
                        residual_inr::fleet::stream::parse_deadline(d).map_err(|e| anyhow!(e))?;
                    (Some(secs), shed)
                }
                None => (None, false),
            };
            fc.stream = Some(residual_inr::fleet::StreamConfig {
                arrivals: residual_inr::fleet::ArrivalSpec::from_name(spec)
                    .map_err(|e| anyhow!(e))?,
                horizon: args.get_f64("horizon", 0.0).map_err(|e| anyhow!(e))?,
                deadline,
                shed,
            });
        }
        (Some(_), None) => {
            return Err(anyhow!("--arrivals requires --horizon SECONDS (the arrival wall)"));
        }
        (None, Some(_)) => {
            return Err(anyhow!("--horizon requires --arrivals (poisson:RATE|diurnal:RATE,PERIOD)"));
        }
        (None, None) => {
            if args.get("deadline").is_some() {
                return Err(anyhow!("--deadline requires a streaming run (--arrivals/--horizon)"));
            }
        }
    }
    if let Some(spec) = args.get("handover") {
        fc.handovers =
            residual_inr::fleet::stream::parse_handovers(spec).map_err(|e| anyhow!(e))?;
    }
    if let Some(spec) = args.get("fail") {
        fc.fail = Some(residual_inr::fleet::stream::parse_fail(spec).map_err(|e| anyhow!(e))?);
    }
    if let Some(spec) = args.get("depart") {
        fc.departs = residual_inr::fleet::stream::parse_departs(spec).map_err(|e| anyhow!(e))?;
    }
    let report = residual_inr::fleet::run(&cfg, &fc)?;
    report.print();
    Ok(())
}

fn compress(args: &Args) -> Result<()> {
    use residual_inr::coordinator::FogNode;
    use residual_inr::data::generate_dataset;
    let cfg = ArchConfig::load_default()?;
    let quality = args.get_usize("quality", 85).map_err(|e| anyhow!(e))? as u8;
    let method = parse_method(args.get_or("method", "res-rapid"), quality)?;
    let profile = Profile::from_name(args.get_or("profile", "dac-sdc"))
        .ok_or_else(|| anyhow!("unknown profile"))?;
    let max = args.get_usize("max-frames", 8).map_err(|e| anyhow!(e))?;
    let session = parse_backend(args)?.open()?;
    println!("backend           : {}", session.backend_name());
    let fog = FogNode::new(&session, &cfg, EncoderConfig::fast());
    let mut ds = generate_dataset(profile, args.get_u64("seed", 7).map_err(|e| anyhow!(e))?, 1);
    ds.sequences[0].frames.truncate(max);
    ds.sequences[0].boxes.truncate(max);
    let c = fog.compress(&ds, method)?;
    println!("method            : {}", c.method.name());
    println!("frames            : {}", c.n_frames);
    println!("records           : {}", c.records.len());
    println!("payload           : {}", fmt_bytes(c.payload_bytes as u64));
    println!("avg frame payload : {}", fmt_bytes(c.avg_frame_bytes() as u64));
    println!(
        "encode time       : {:.2} s ({} Adam steps, {:.2e} s/step)",
        c.encode_seconds,
        c.encode_steps,
        c.seconds_per_step()
    );
    Ok(())
}

fn commmodel(args: &Args) -> Result<()> {
    use residual_inr::commmodel as cm;
    let k = args.get_usize("devices", 10).map_err(|e| anyhow!(e))?;
    let alpha = args.get_f64("alpha", 0.15).map_err(|e| anyhow!(e))?;
    let m = 1e6;
    let s = cm::serverless_total(&cm::uniform_all_to_all(k, m, false));
    let f = cm::fog_total(&cm::uniform_all_to_all(k, m, true), alpha);
    println!("k = {k} devices, α = {alpha}, m = 1 MB/device, all-to-all");
    println!("serverless D_s = {}", fmt_bytes(s as u64));
    println!("fog        D_f = {}", fmt_bytes(f as u64));
    println!("reduction      = {:.2}x", s / f);
    match cm::min_receivers_for_fog(alpha) {
        Some(n) => println!("fog beneficial from n_i >= {n} receivers (n_i > 1/(1-a))"),
        None => println!("fog never beneficial at a >= 1"),
    }
    Ok(())
}

fn info() -> Result<()> {
    use residual_inr::runtime::Manifest;
    let cfg = ArchConfig::load_default()?;
    println!("frame: {}x{}", cfg.frame_w, cfg.frame_h);
    match Manifest::load_default() {
        Ok(m) => println!("artifacts: {} (auto backend: pjrt)", m.entries.len()),
        Err(_) => println!("artifacts: none (auto backend: native SIMD engine)"),
    }
    println!(
        "native kernels: {} (set RESIDUAL_INR_NO_SIMD=1 for scalar)",
        residual_inr::inr::nn::active().name()
    );
    for p in Profile::ALL {
        let rp = cfg.rapid(p);
        println!(
            "{:8} bg {}x{} ({} params)  baseline {}x{} ({} params)  obj bins: {}",
            p.name(),
            rp.background.layers,
            rp.background.hidden,
            rp.background.param_count(),
            rp.baseline.layers,
            rp.baseline.hidden,
            rp.baseline.param_count(),
            rp.object_bins
                .iter()
                .map(|b| format!("{}x{}@{}", b.arch.layers, b.arch.hidden, b.max_side))
                .collect::<Vec<_>>()
                .join(" "),
        );
    }
    Ok(())
}

//! Micro-benchmark harness for the `harness = false` bench targets
//! (no `criterion` in the vendored crate set). Provides warmup + timed
//! iterations with summary statistics, and paper-style table printing
//! shared by the per-figure bench binaries.

use crate::metrics::stats::{summarize, Summary};
use crate::util::Stopwatch;

/// Result of timing one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall time in seconds.
    pub stats: Summary,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.stats.mean * 1e3
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.seconds());
    }
    BenchResult { name: name.to_string(), iters, stats: summarize(&samples) }
}

/// Time until at least `min_total_secs` has elapsed (at least 3 iters).
pub fn bench_for<F: FnMut()>(name: &str, min_total_secs: f64, mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::new();
    let total = Stopwatch::start();
    while samples.len() < 3 || total.seconds() < min_total_secs {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.seconds());
        if samples.len() > 10_000 {
            break;
        }
    }
    BenchResult { name: name.to_string(), iters: samples.len(), stats: summarize(&samples) }
}

/// Print a bench result in a compact fixed-width row.
pub fn report(r: &BenchResult) {
    println!(
        "{:<44} {:>10.3} ms/iter  (±{:>7.3} ms, n={}, p95 {:.3} ms)",
        r.name,
        r.stats.mean * 1e3,
        r.stats.std * 1e3,
        r.iters,
        r.stats.p95 * 1e3,
    );
}

/// Fixed-width table printer for paper-style figure/table reproduction.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

/// ASCII bar for quick visual comparison in bench output.
pub fn bar(value: f64, max: f64, width: usize) -> String {
    let n = if max > 0.0 { ((value / max) * width as f64).round() as usize } else { 0 };
    "#".repeat(n.min(width))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_expected_iters() {
        let mut count = 0;
        let r = bench("t", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.stats.mean >= 0.0);
    }

    #[test]
    fn bench_for_hits_min_time() {
        let r = bench_for("t", 0.01, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(r.iters >= 3);
        assert!(r.stats.mean >= 0.0005);
    }

    #[test]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()])
        }));
        assert!(res.is_err());
    }

    #[test]
    fn bar_scales() {
        assert_eq!(bar(5.0, 10.0, 10), "#####");
        assert_eq!(bar(0.0, 10.0, 10), "");
        assert_eq!(bar(20.0, 10.0, 10), "##########");
    }
}

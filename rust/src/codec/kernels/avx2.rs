//! AVX2 kernels (x86_64). Eight f32 lanes per op via `std::arch`.
//!
//! Every kernel performs the same multiplies and adds in the same
//! association order as the scalar code in `jpeg::{dct,color}` — separate
//! `mul`/`add`, never FMA, accumulators seeded from `+0.0` — so results
//! are bit-identical to scalar (the parity tests in `kernels::tests`
//! compare with `==`). The only admitted divergence is NaN handling in
//! the final clamp (`min`/`max` vs `f32::clamp`), which cannot trigger on
//! finite planes.
//!
//! Safety: every function here requires AVX2; callers in `kernels` only
//! dispatch after `is_x86_feature_detected!("avx2")` succeeded.

use std::arch::x86_64::*;

/// Forward 8×8 DCT-II: lanes are the eight coefficients `u` of one row.
///
/// `c` is the cosine basis `c[u][x]`, `t` its transpose `t[x][u]`.
///
/// # Safety
/// Requires AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn fdct8x8(block: &[f32; 64], c: &[[f32; 8]; 8], t: &[[f32; 8]; 8]) -> [f32; 64] {
    // Rows first: tmp[y][u] = Σ_x block[y][x] c[u][x], lanes = u.
    let mut tmp = [0.0f32; 64];
    for y in 0..8 {
        let mut acc = _mm256_setzero_ps();
        for x in 0..8 {
            let s = _mm256_set1_ps(block[y * 8 + x]);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(s, _mm256_loadu_ps(t[x].as_ptr())));
        }
        _mm256_storeu_ps(tmp.as_mut_ptr().add(y * 8), acc);
    }
    // Columns: out[v][u] = Σ_y tmp[y][u] c[v][y], lanes = u.
    let mut out = [0.0f32; 64];
    for v in 0..8 {
        let mut acc = _mm256_setzero_ps();
        for y in 0..8 {
            let row = _mm256_loadu_ps(tmp.as_ptr().add(y * 8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(row, _mm256_set1_ps(c[v][y])));
        }
        _mm256_storeu_ps(out.as_mut_ptr().add(v * 8), acc);
    }
    out
}

/// Inverse 8×8 DCT: same lane layout as [`fdct8x8`].
///
/// # Safety
/// Requires AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn idct8x8(coef: &[f32; 64], c: &[[f32; 8]; 8], _t: &[[f32; 8]; 8]) -> [f32; 64] {
    // Columns first: tmp[y][u] = Σ_v coef[v][u] c[v][y], lanes = u.
    let mut tmp = [0.0f32; 64];
    for y in 0..8 {
        let mut acc = _mm256_setzero_ps();
        for v in 0..8 {
            let row = _mm256_loadu_ps(coef.as_ptr().add(v * 8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(row, _mm256_set1_ps(c[v][y])));
        }
        _mm256_storeu_ps(tmp.as_mut_ptr().add(y * 8), acc);
    }
    // Rows: out[y][x] = Σ_u tmp[y][u] c[u][x], lanes = x.
    let mut out = [0.0f32; 64];
    for y in 0..8 {
        let mut acc = _mm256_setzero_ps();
        for u in 0..8 {
            let s = _mm256_set1_ps(tmp[y * 8 + u]);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(s, _mm256_loadu_ps(c[u].as_ptr())));
        }
        _mm256_storeu_ps(out.as_mut_ptr().add(y * 8), acc);
    }
    out
}

/// Deinterleave 8 RGB pixels (3 consecutive vectors) into r/g/b vectors.
/// Index maps verified against the scalar layout in `kernels::tests`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn deinterleave8(v0: __m256, v1: __m256, v2: __m256) -> (__m256, __m256, __m256) {
    let r = _mm256_blend_ps::<0b1100_0000>(
        _mm256_blend_ps::<0b0011_1000>(
            _mm256_permutevar8x32_ps(v0, _mm256_setr_epi32(0, 3, 6, 0, 0, 0, 0, 0)),
            _mm256_permutevar8x32_ps(v1, _mm256_setr_epi32(0, 0, 0, 1, 4, 7, 0, 0)),
        ),
        _mm256_permutevar8x32_ps(v2, _mm256_setr_epi32(0, 0, 0, 0, 0, 0, 2, 5)),
    );
    let g = _mm256_blend_ps::<0b1110_0000>(
        _mm256_blend_ps::<0b0001_1000>(
            _mm256_permutevar8x32_ps(v0, _mm256_setr_epi32(1, 4, 7, 0, 0, 0, 0, 0)),
            _mm256_permutevar8x32_ps(v1, _mm256_setr_epi32(0, 0, 0, 2, 5, 0, 0, 0)),
        ),
        _mm256_permutevar8x32_ps(v2, _mm256_setr_epi32(0, 0, 0, 0, 0, 0, 3, 6)),
    );
    let b = _mm256_blend_ps::<0b1110_0000>(
        _mm256_blend_ps::<0b0001_1100>(
            _mm256_permutevar8x32_ps(v0, _mm256_setr_epi32(2, 5, 0, 0, 0, 0, 0, 0)),
            _mm256_permutevar8x32_ps(v1, _mm256_setr_epi32(0, 0, 0, 3, 6, 0, 0, 0)),
        ),
        _mm256_permutevar8x32_ps(v2, _mm256_setr_epi32(0, 0, 0, 0, 0, 1, 4, 7)),
    );
    (r, g, b)
}

/// Interleave r/g/b vectors back into 3 consecutive RGB vectors.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn interleave8(r: __m256, g: __m256, b: __m256) -> (__m256, __m256, __m256) {
    let o0 = _mm256_blend_ps::<0b0010_0100>(
        _mm256_blend_ps::<0b1001_0010>(
            _mm256_permutevar8x32_ps(r, _mm256_setr_epi32(0, 0, 0, 1, 1, 1, 2, 2)),
            _mm256_permutevar8x32_ps(g, _mm256_setr_epi32(0, 0, 0, 0, 1, 1, 1, 2)),
        ),
        _mm256_permutevar8x32_ps(b, _mm256_setr_epi32(0, 0, 0, 0, 0, 1, 1, 1)),
    );
    let o1 = _mm256_blend_ps::<0b0010_0100>(
        _mm256_blend_ps::<0b1001_0010>(
            _mm256_permutevar8x32_ps(b, _mm256_setr_epi32(2, 2, 2, 3, 3, 3, 4, 4)),
            _mm256_permutevar8x32_ps(r, _mm256_setr_epi32(3, 3, 3, 3, 4, 4, 4, 5)),
        ),
        _mm256_permutevar8x32_ps(g, _mm256_setr_epi32(3, 3, 3, 3, 3, 4, 4, 4)),
    );
    let o2 = _mm256_blend_ps::<0b0010_0100>(
        _mm256_blend_ps::<0b1001_0010>(
            _mm256_permutevar8x32_ps(g, _mm256_setr_epi32(5, 5, 5, 6, 6, 6, 7, 7)),
            _mm256_permutevar8x32_ps(b, _mm256_setr_epi32(5, 5, 5, 5, 6, 6, 6, 7)),
        ),
        _mm256_permutevar8x32_ps(r, _mm256_setr_epi32(6, 6, 6, 6, 6, 7, 7, 7)),
    );
    (o0, o1, o2)
}

/// Bulk RGB→YCbCr over the leading `8·⌊n/8⌋` pixels; returns how many
/// pixels were processed (caller finishes the tail with scalar code).
///
/// # Safety
/// Requires AVX2. `y`/`cb`/`cr` must each hold `rgb01.len() / 3` floats.
#[target_feature(enable = "avx2")]
pub unsafe fn rgb_to_ycbcr(rgb01: &[f32], y: &mut [f32], cb: &mut [f32], cr: &mut [f32]) -> usize {
    let n = rgb01.len() / 3;
    let scale = _mm256_set1_ps(255.0);
    let c128 = _mm256_set1_ps(128.0);
    for i in 0..n / 8 {
        let base = i * 24;
        let v0 = _mm256_loadu_ps(rgb01.as_ptr().add(base));
        let v1 = _mm256_loadu_ps(rgb01.as_ptr().add(base + 8));
        let v2 = _mm256_loadu_ps(rgb01.as_ptr().add(base + 16));
        let (r, g, b) = deinterleave8(v0, v1, v2);
        let r = _mm256_mul_ps(r, scale);
        let g = _mm256_mul_ps(g, scale);
        let b = _mm256_mul_ps(b, scale);
        // y = 0.299 r + 0.587 g + 0.114 b
        let yv = _mm256_add_ps(
            _mm256_add_ps(
                _mm256_mul_ps(_mm256_set1_ps(0.299), r),
                _mm256_mul_ps(_mm256_set1_ps(0.587), g),
            ),
            _mm256_mul_ps(_mm256_set1_ps(0.114), b),
        );
        // cb = ((128 - 0.168736 r) - 0.331264 g) + 0.5 b
        let cbv = _mm256_add_ps(
            _mm256_sub_ps(
                _mm256_sub_ps(c128, _mm256_mul_ps(_mm256_set1_ps(0.168_736), r)),
                _mm256_mul_ps(_mm256_set1_ps(0.331_264), g),
            ),
            _mm256_mul_ps(_mm256_set1_ps(0.5), b),
        );
        // cr = ((128 + 0.5 r) - 0.418688 g) - 0.081312 b
        let crv = _mm256_sub_ps(
            _mm256_sub_ps(
                _mm256_add_ps(c128, _mm256_mul_ps(_mm256_set1_ps(0.5), r)),
                _mm256_mul_ps(_mm256_set1_ps(0.418_688), g),
            ),
            _mm256_mul_ps(_mm256_set1_ps(0.081_312), b),
        );
        _mm256_storeu_ps(y.as_mut_ptr().add(i * 8), yv);
        _mm256_storeu_ps(cb.as_mut_ptr().add(i * 8), cbv);
        _mm256_storeu_ps(cr.as_mut_ptr().add(i * 8), crv);
    }
    n / 8 * 8
}

/// Bulk YCbCr→RGB over the leading `8·⌊n/8⌋` pixels; returns how many
/// pixels were processed.
///
/// # Safety
/// Requires AVX2. `rgb` must hold `3 · y.len()` floats.
#[target_feature(enable = "avx2")]
pub unsafe fn ycbcr_to_rgb(y: &[f32], cb: &[f32], cr: &[f32], rgb: &mut [f32]) -> usize {
    let n = y.len();
    let c128 = _mm256_set1_ps(128.0);
    let inv = _mm256_set1_ps(255.0);
    let zero = _mm256_setzero_ps();
    let one = _mm256_set1_ps(1.0);
    for i in 0..n / 8 {
        let yy = _mm256_loadu_ps(y.as_ptr().add(i * 8));
        let cbv = _mm256_sub_ps(_mm256_loadu_ps(cb.as_ptr().add(i * 8)), c128);
        let crv = _mm256_sub_ps(_mm256_loadu_ps(cr.as_ptr().add(i * 8)), c128);
        // r = yy + 1.402 cr
        let r = _mm256_add_ps(yy, _mm256_mul_ps(_mm256_set1_ps(1.402), crv));
        // g = (yy - 0.344136 cb) - 0.714136 cr
        let g = _mm256_sub_ps(
            _mm256_sub_ps(yy, _mm256_mul_ps(_mm256_set1_ps(0.344_136), cbv)),
            _mm256_mul_ps(_mm256_set1_ps(0.714_136), crv),
        );
        // b = yy + 1.772 cb
        let b = _mm256_add_ps(yy, _mm256_mul_ps(_mm256_set1_ps(1.772), cbv));
        let r = _mm256_max_ps(_mm256_min_ps(_mm256_div_ps(r, inv), one), zero);
        let g = _mm256_max_ps(_mm256_min_ps(_mm256_div_ps(g, inv), one), zero);
        let b = _mm256_max_ps(_mm256_min_ps(_mm256_div_ps(b, inv), one), zero);
        let (o0, o1, o2) = interleave8(r, g, b);
        let base = i * 24;
        _mm256_storeu_ps(rgb.as_mut_ptr().add(base), o0);
        _mm256_storeu_ps(rgb.as_mut_ptr().add(base + 8), o1);
        _mm256_storeu_ps(rgb.as_mut_ptr().add(base + 16), o2);
    }
    n / 8 * 8
}

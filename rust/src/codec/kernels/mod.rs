//! Lane-parallel kernels for the measured JPEG hot paths, behind runtime
//! dispatch.
//!
//! Three hot paths are covered (see `benches/codec_hotpath.rs`):
//!
//! - **8×8 forward/inverse DCT** — the eight coefficients of a row are
//!   computed as eight f32 lanes against the transposed cosine basis.
//! - **`rgb_to_ycbcr` / `ycbcr_to_rgb`** — 8 pixels (AVX2) or 4 pixels
//!   (NEON) per iteration over the contiguous interleaved plane, with a
//!   scalar tail for the remainder.
//! - **Batched Huffman emission** — lives in `jpeg::bitio::BitWriter`
//!   (u64 accumulator) rather than here; `coder::write_component` packs
//!   `code ‖ magnitude` into one `write_u64` call per symbol.
//!
//! ## Dispatch matrix
//!
//! | target | backend | gate |
//! |---|---|---|
//! | `x86_64` with AVX2 | [`Backend::Avx2`] | `is_x86_feature_detected!("avx2")` |
//! | `aarch64` | [`Backend::Neon`] | always (NEON is baseline on aarch64) |
//! | anything else | [`Backend::Scalar`] | — |
//!
//! Setting `RESIDUAL_INR_NO_SIMD=1` in the environment forces
//! [`Backend::Scalar`] regardless of CPU features (decided once, at first
//! use). The scalar code in `jpeg::{dct,color}` is retained verbatim and
//! is the always-compiled oracle.
//!
//! ## Bit-exactness
//!
//! The ISSUE phrasing says "fused multiply-add", but FMA changes rounding
//! and would make the emitted bitstream depend on the host CPU. The SIMD
//! kernels therefore use separate multiply and add in the *same
//! association order* as the scalar loops, which makes every backend
//! bit-identical to scalar (exactness tests below compare with `==`; the
//! only tolerated difference is the sign of exact zeros, which the
//! accumulators avoid by starting from `+0.0` exactly like the scalar
//! code). `RESIDUAL_INR_NO_SIMD=1` therefore yields byte-identical
//! bitstreams, and DCT accuracy is additionally property-tested against
//! the O(n⁴) reference transform.

use super::jpeg::color::{self, Plane};
use super::jpeg::dct;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;

/// A dispatchable kernel implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The verbatim scalar code in `jpeg::{dct,color}` — always compiled.
    Scalar,
    /// AVX2 lanes via `std::arch::x86_64` (runtime-detected).
    Avx2,
    /// NEON lanes via `std::arch::aarch64` (baseline on aarch64).
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }
}

/// Every backend usable on this machine, scalar first. Tests iterate this
/// to hold each dispatched kernel to the scalar oracle.
pub fn available_backends() -> Vec<Backend> {
    let mut v = vec![Backend::Scalar];
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        v.push(Backend::Avx2);
    }
    #[cfg(target_arch = "aarch64")]
    v.push(Backend::Neon);
    v
}

/// The backend the dispatching entry points use: the best available one,
/// unless `RESIDUAL_INR_NO_SIMD=1` forces scalar. Decided once.
pub fn active() -> Backend {
    use std::sync::OnceLock;
    static ACTIVE: OnceLock<Backend> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let forced = std::env::var("RESIDUAL_INR_NO_SIMD")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        if forced {
            return Backend::Scalar;
        }
        *available_backends().last().unwrap_or(&Backend::Scalar)
    })
}

/// The cosine basis transposed: `t[x][u] = c[u][x]`, so a row of `t` is
/// the vector of all eight coefficients for one input sample.
#[allow(dead_code)] // scalar-only builds dispatch straight to jpeg::dct
pub(crate) fn basis_t() -> &'static [[f32; 8]; 8] {
    use std::sync::OnceLock;
    static T: OnceLock<[[f32; 8]; 8]> = OnceLock::new();
    T.get_or_init(|| {
        let c = dct::basis_c();
        let mut t = [[0.0f32; 8]; 8];
        for (u, row) in c.iter().enumerate() {
            for (x, &v) in row.iter().enumerate() {
                t[x][u] = v;
            }
        }
        t
    })
}

/// Forward 8×8 DCT-II on the active backend. Row-major block.
pub fn fdct8x8(block: &[f32; 64]) -> [f32; 64] {
    fdct8x8_on(active(), block)
}

/// Inverse 8×8 DCT on the active backend.
pub fn idct8x8(coef: &[f32; 64]) -> [f32; 64] {
    idct8x8_on(active(), coef)
}

/// Interleaved RGB `[0,1]` → Y/Cb/Cr planes `[0,255]` on the active backend.
pub fn rgb_to_ycbcr(width: usize, height: usize, rgb01: &[f32]) -> (Plane, Plane, Plane) {
    rgb_to_ycbcr_on(active(), width, height, rgb01)
}

/// Y/Cb/Cr planes `[0,255]` → interleaved RGB `[0,1]` on the active backend.
pub fn ycbcr_to_rgb(y: &Plane, cb: &Plane, cr: &Plane) -> Vec<f32> {
    ycbcr_to_rgb_on(active(), y, cb, cr)
}

/// [`fdct8x8`] pinned to one backend (tests, benches).
pub fn fdct8x8_on(be: Backend, block: &[f32; 64]) -> [f32; 64] {
    match be {
        Backend::Scalar => dct::fdct8x8(block),
        #[cfg(target_arch = "x86_64")]
        // Safety: Avx2 only enters available_backends()/active() after
        // is_x86_feature_detected!("avx2") succeeded.
        Backend::Avx2 => unsafe { avx2::fdct8x8(block, dct::basis_c(), basis_t()) },
        #[cfg(target_arch = "aarch64")]
        // Safety: NEON is baseline on aarch64 std targets.
        Backend::Neon => unsafe { neon::fdct8x8(block, dct::basis_c(), basis_t()) },
        // A backend this target cannot run falls back to scalar.
        _ => dct::fdct8x8(block),
    }
}

/// [`idct8x8`] pinned to one backend (tests, benches).
pub fn idct8x8_on(be: Backend, coef: &[f32; 64]) -> [f32; 64] {
    match be {
        Backend::Scalar => dct::idct8x8(coef),
        #[cfg(target_arch = "x86_64")]
        // Safety: see fdct8x8_on.
        Backend::Avx2 => unsafe { avx2::idct8x8(coef, dct::basis_c(), basis_t()) },
        #[cfg(target_arch = "aarch64")]
        // Safety: see fdct8x8_on.
        Backend::Neon => unsafe { neon::idct8x8(coef, dct::basis_c(), basis_t()) },
        _ => dct::idct8x8(coef),
    }
}

/// [`rgb_to_ycbcr`] pinned to one backend (tests, benches).
pub fn rgb_to_ycbcr_on(
    be: Backend,
    width: usize,
    height: usize,
    rgb01: &[f32],
) -> (Plane, Plane, Plane) {
    assert_eq!(rgb01.len(), width * height * 3);
    if be == Backend::Scalar {
        return color::rgb_to_ycbcr(width, height, rgb01);
    }
    // SIMD bulk over the leading pixels, then the verbatim scalar tail.
    let mut y = Plane::zeros(width, height);
    let mut cb = Plane::zeros(width, height);
    let mut cr = Plane::zeros(width, height);
    let done = match be {
        #[cfg(target_arch = "x86_64")]
        // Safety: see fdct8x8_on.
        Backend::Avx2 => unsafe {
            avx2::rgb_to_ycbcr(rgb01, &mut y.data, &mut cb.data, &mut cr.data)
        },
        #[cfg(target_arch = "aarch64")]
        // Safety: see fdct8x8_on.
        Backend::Neon => unsafe {
            neon::rgb_to_ycbcr(rgb01, &mut y.data, &mut cb.data, &mut cr.data)
        },
        // A backend this target cannot run processes nothing here; the
        // scalar tail below covers the whole plane.
        _ => 0,
    };
    for i in done..width * height {
        let r = rgb01[3 * i] * 255.0;
        let g = rgb01[3 * i + 1] * 255.0;
        let b = rgb01[3 * i + 2] * 255.0;
        y.data[i] = 0.299 * r + 0.587 * g + 0.114 * b;
        cb.data[i] = 128.0 - 0.168_736 * r - 0.331_264 * g + 0.5 * b;
        cr.data[i] = 128.0 + 0.5 * r - 0.418_688 * g - 0.081_312 * b;
    }
    (y, cb, cr)
}

/// [`ycbcr_to_rgb`] pinned to one backend (tests, benches).
pub fn ycbcr_to_rgb_on(be: Backend, y: &Plane, cb: &Plane, cr: &Plane) -> Vec<f32> {
    assert_eq!((y.width, y.height), (cb.width, cb.height));
    assert_eq!((y.width, y.height), (cr.width, cr.height));
    let n = y.width * y.height;
    if be == Backend::Scalar {
        return color::ycbcr_to_rgb(y, cb, cr);
    }
    let mut rgb = vec![0.0f32; n * 3];
    let done = match be {
        #[cfg(target_arch = "x86_64")]
        // Safety: see fdct8x8_on.
        Backend::Avx2 => unsafe {
            avx2::ycbcr_to_rgb(&y.data, &cb.data, &cr.data, &mut rgb)
        },
        #[cfg(target_arch = "aarch64")]
        // Safety: see fdct8x8_on.
        Backend::Neon => unsafe {
            neon::ycbcr_to_rgb(&y.data, &cb.data, &cr.data, &mut rgb)
        },
        _ => 0,
    };
    for i in done..n {
        let yy = y.data[i];
        let cbv = cb.data[i] - 128.0;
        let crv = cr.data[i] - 128.0;
        let r = yy + 1.402 * crv;
        let g = yy - 0.344_136 * cbv - 0.714_136 * crv;
        let b = yy + 1.772 * cbv;
        rgb[3 * i] = (r / 255.0).clamp(0.0, 1.0);
        rgb[3 * i + 1] = (g / 255.0).clamp(0.0, 1.0);
        rgb[3 * i + 2] = (b / 255.0).clamp(0.0, 1.0);
    }
    rgb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_block(seed: u64) -> [f32; 64] {
        let mut rng = Pcg32::seeded(seed);
        let mut b = [0.0f32; 64];
        for v in &mut b {
            *v = rng.range_f32(-128.0, 128.0);
        }
        b
    }

    /// Blocks that stress edge behavior: constants at the range limits,
    /// impulses, alternating extremes.
    fn edge_blocks() -> Vec<[f32; 64]> {
        let mut blocks = vec![[0.0f32; 64], [128.0; 64], [-128.0; 64], [255.0; 64]];
        let mut impulse = [0.0f32; 64];
        impulse[0] = 255.0;
        impulse[63] = -255.0;
        blocks.push(impulse);
        let mut alt = [0.0f32; 64];
        for (i, v) in alt.iter_mut().enumerate() {
            *v = if i % 2 == 0 { 128.0 } else { -128.0 };
        }
        blocks.push(alt);
        blocks
    }

    fn test_images() -> Vec<(usize, usize, Vec<f32>)> {
        let mut rng = Pcg32::seeded(90);
        // Widths chosen so n % 8 covers 0 and several nonzero tails.
        let mut imgs = Vec::new();
        for (w, h) in [(16, 8), (13, 5), (7, 3), (1, 1), (8, 1)] {
            let img: Vec<f32> = (0..w * h * 3).map(|_| rng.f32()).collect();
            imgs.push((w, h, img));
        }
        // Edge values: all 0, all 1, alternating channel extremes.
        imgs.push((9, 4, vec![0.0; 9 * 4 * 3]));
        imgs.push((9, 4, vec![1.0; 9 * 4 * 3]));
        imgs.push((10, 2, (0..10 * 2 * 3).map(|i| (i % 2) as f32).collect()));
        imgs
    }

    #[test]
    fn every_backend_matches_scalar_dct_exactly() {
        for be in available_backends() {
            let mut blocks = edge_blocks();
            for seed in 0..16 {
                blocks.push(rand_block(seed));
            }
            for b in &blocks {
                let want_f = dct::fdct8x8(b);
                let got_f = fdct8x8_on(be, b);
                assert_eq!(want_f, got_f, "fdct mismatch on {}", be.name());
                let want_i = dct::idct8x8(&want_f);
                let got_i = idct8x8_on(be, &want_f);
                assert_eq!(want_i, got_i, "idct mismatch on {}", be.name());
            }
        }
    }

    #[test]
    fn every_backend_matches_scalar_color_exactly() {
        for be in available_backends() {
            for (w, h, img) in test_images() {
                let (sy, scb, scr) = color::rgb_to_ycbcr(w, h, &img);
                let (ky, kcb, kcr) = rgb_to_ycbcr_on(be, w, h, &img);
                assert_eq!(sy.data, ky.data, "Y mismatch on {}", be.name());
                assert_eq!(scb.data, kcb.data, "Cb mismatch on {}", be.name());
                assert_eq!(scr.data, kcr.data, "Cr mismatch on {}", be.name());
                let want = color::ycbcr_to_rgb(&sy, &scb, &scr);
                let got = ycbcr_to_rgb_on(be, &ky, &kcb, &kcr);
                assert_eq!(want, got, "rgb mismatch on {}", be.name());
            }
        }
    }

    /// Satellite: `idct8x8(fdct8x8(block))` within 1e-3 of identity on
    /// random and edge-value blocks, for scalar and every dispatched kernel.
    #[test]
    fn property_dct_roundtrip_identity_all_backends() {
        for be in available_backends() {
            let mut blocks = edge_blocks();
            for seed in 200..216 {
                blocks.push(rand_block(seed));
            }
            for b in &blocks {
                let r = idct8x8_on(be, &fdct8x8_on(be, b));
                for i in 0..64 {
                    assert!(
                        (b[i] - r[i]).abs() < 1e-3,
                        "{}: i={i} {} vs {}",
                        be.name(),
                        b[i],
                        r[i]
                    );
                }
            }
        }
    }

    /// Satellite: color roundtrip within quantization tolerance (2/255)
    /// for every backend, random and edge-value images.
    #[test]
    fn property_color_roundtrip_all_backends() {
        for be in available_backends() {
            for (w, h, img) in test_images() {
                let (y, cb, cr) = rgb_to_ycbcr_on(be, w, h, &img);
                let back = ycbcr_to_rgb_on(be, &y, &cb, &cr);
                for (a, b) in img.iter().zip(&back) {
                    assert!((a - b).abs() < 2.0 / 255.0, "{}: {a} vs {b}", be.name());
                }
            }
        }
    }

    /// The dispatched fdct stays within bounded error of the O(n⁴)
    /// reference transform (same bound the scalar fast path is held to).
    #[test]
    fn dispatched_fdct_matches_reference_bounded() {
        for be in available_backends() {
            for seed in 300..308 {
                let b = rand_block(seed);
                let fast = fdct8x8_on(be, &b);
                let slow = dct::fdct8x8_reference(&b);
                for i in 0..64 {
                    assert!(
                        (fast[i] - slow[i]).abs() < 1e-2,
                        "{}: i={i} {} vs {}",
                        be.name(),
                        fast[i],
                        slow[i]
                    );
                }
            }
        }
    }

    #[test]
    fn active_backend_is_available() {
        assert!(available_backends().contains(&active()));
    }
}

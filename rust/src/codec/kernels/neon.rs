//! NEON kernels (aarch64). Four f32 lanes per op via `std::arch`; the
//! 8-wide DCT rows are processed as two `float32x4` halves.
//!
//! Same contract as the AVX2 module: separate `vmulq`/`vaddq` in the
//! scalar association order (never `vfmaq`), accumulators seeded from
//! `+0.0`, so every result is bit-identical to the scalar oracle. The
//! interleaved color planes use `vld3q_f32`/`vst3q_f32`, which
//! de/re-interleave 4 RGB pixels per call for free.
//!
//! Safety: NEON is baseline on aarch64 std targets, so these kernels are
//! always callable there; `kernels::available_backends` only offers
//! `Backend::Neon` on aarch64.

use std::arch::aarch64::*;

/// Forward 8×8 DCT-II: lanes are coefficients `u`, in two halves.
///
/// `c` is the cosine basis `c[u][x]`, `t` its transpose `t[x][u]`.
///
/// # Safety
/// Requires NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn fdct8x8(block: &[f32; 64], c: &[[f32; 8]; 8], t: &[[f32; 8]; 8]) -> [f32; 64] {
    // Rows first: tmp[y][u] = Σ_x block[y][x] c[u][x], lanes = u.
    let mut tmp = [0.0f32; 64];
    for y in 0..8 {
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for x in 0..8 {
            let s = vdupq_n_f32(block[y * 8 + x]);
            lo = vaddq_f32(lo, vmulq_f32(s, vld1q_f32(t[x].as_ptr())));
            hi = vaddq_f32(hi, vmulq_f32(s, vld1q_f32(t[x].as_ptr().add(4))));
        }
        vst1q_f32(tmp.as_mut_ptr().add(y * 8), lo);
        vst1q_f32(tmp.as_mut_ptr().add(y * 8 + 4), hi);
    }
    // Columns: out[v][u] = Σ_y tmp[y][u] c[v][y], lanes = u.
    let mut out = [0.0f32; 64];
    for v in 0..8 {
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for y in 0..8 {
            let s = vdupq_n_f32(c[v][y]);
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(tmp.as_ptr().add(y * 8)), s));
            hi = vaddq_f32(hi, vmulq_f32(vld1q_f32(tmp.as_ptr().add(y * 8 + 4)), s));
        }
        vst1q_f32(out.as_mut_ptr().add(v * 8), lo);
        vst1q_f32(out.as_mut_ptr().add(v * 8 + 4), hi);
    }
    out
}

/// Inverse 8×8 DCT: same lane layout as [`fdct8x8`].
///
/// # Safety
/// Requires NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn idct8x8(coef: &[f32; 64], c: &[[f32; 8]; 8], _t: &[[f32; 8]; 8]) -> [f32; 64] {
    // Columns first: tmp[y][u] = Σ_v coef[v][u] c[v][y], lanes = u.
    let mut tmp = [0.0f32; 64];
    for y in 0..8 {
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for v in 0..8 {
            let s = vdupq_n_f32(c[v][y]);
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(coef.as_ptr().add(v * 8)), s));
            hi = vaddq_f32(hi, vmulq_f32(vld1q_f32(coef.as_ptr().add(v * 8 + 4)), s));
        }
        vst1q_f32(tmp.as_mut_ptr().add(y * 8), lo);
        vst1q_f32(tmp.as_mut_ptr().add(y * 8 + 4), hi);
    }
    // Rows: out[y][x] = Σ_u tmp[y][u] c[u][x], lanes = x.
    let mut out = [0.0f32; 64];
    for y in 0..8 {
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for u in 0..8 {
            let s = vdupq_n_f32(tmp[y * 8 + u]);
            lo = vaddq_f32(lo, vmulq_f32(s, vld1q_f32(c[u].as_ptr())));
            hi = vaddq_f32(hi, vmulq_f32(s, vld1q_f32(c[u].as_ptr().add(4))));
        }
        vst1q_f32(out.as_mut_ptr().add(y * 8), lo);
        vst1q_f32(out.as_mut_ptr().add(y * 8 + 4), hi);
    }
    out
}

/// Bulk RGB→YCbCr over the leading `4·⌊n/4⌋` pixels; returns how many
/// pixels were processed (caller finishes the tail with scalar code).
///
/// # Safety
/// Requires NEON. `y`/`cb`/`cr` must each hold `rgb01.len() / 3` floats.
#[target_feature(enable = "neon")]
pub unsafe fn rgb_to_ycbcr(rgb01: &[f32], y: &mut [f32], cb: &mut [f32], cr: &mut [f32]) -> usize {
    let n = rgb01.len() / 3;
    let scale = vdupq_n_f32(255.0);
    let c128 = vdupq_n_f32(128.0);
    for i in 0..n / 4 {
        let px = vld3q_f32(rgb01.as_ptr().add(i * 12));
        let r = vmulq_f32(px.0, scale);
        let g = vmulq_f32(px.1, scale);
        let b = vmulq_f32(px.2, scale);
        // y = 0.299 r + 0.587 g + 0.114 b
        let yv = vaddq_f32(
            vaddq_f32(
                vmulq_f32(vdupq_n_f32(0.299), r),
                vmulq_f32(vdupq_n_f32(0.587), g),
            ),
            vmulq_f32(vdupq_n_f32(0.114), b),
        );
        // cb = ((128 - 0.168736 r) - 0.331264 g) + 0.5 b
        let cbv = vaddq_f32(
            vsubq_f32(
                vsubq_f32(c128, vmulq_f32(vdupq_n_f32(0.168_736), r)),
                vmulq_f32(vdupq_n_f32(0.331_264), g),
            ),
            vmulq_f32(vdupq_n_f32(0.5), b),
        );
        // cr = ((128 + 0.5 r) - 0.418688 g) - 0.081312 b
        let crv = vsubq_f32(
            vsubq_f32(
                vaddq_f32(c128, vmulq_f32(vdupq_n_f32(0.5), r)),
                vmulq_f32(vdupq_n_f32(0.418_688), g),
            ),
            vmulq_f32(vdupq_n_f32(0.081_312), b),
        );
        vst1q_f32(y.as_mut_ptr().add(i * 4), yv);
        vst1q_f32(cb.as_mut_ptr().add(i * 4), cbv);
        vst1q_f32(cr.as_mut_ptr().add(i * 4), crv);
    }
    n / 4 * 4
}

/// Bulk YCbCr→RGB over the leading `4·⌊n/4⌋` pixels; returns how many
/// pixels were processed.
///
/// # Safety
/// Requires NEON. `rgb` must hold `3 · y.len()` floats.
#[target_feature(enable = "neon")]
pub unsafe fn ycbcr_to_rgb(y: &[f32], cb: &[f32], cr: &[f32], rgb: &mut [f32]) -> usize {
    let n = y.len();
    let c128 = vdupq_n_f32(128.0);
    let inv = vdupq_n_f32(255.0);
    let zero = vdupq_n_f32(0.0);
    let one = vdupq_n_f32(1.0);
    for i in 0..n / 4 {
        let yy = vld1q_f32(y.as_ptr().add(i * 4));
        let cbv = vsubq_f32(vld1q_f32(cb.as_ptr().add(i * 4)), c128);
        let crv = vsubq_f32(vld1q_f32(cr.as_ptr().add(i * 4)), c128);
        // r = yy + 1.402 cr
        let r = vaddq_f32(yy, vmulq_f32(vdupq_n_f32(1.402), crv));
        // g = (yy - 0.344136 cb) - 0.714136 cr
        let g = vsubq_f32(
            vsubq_f32(yy, vmulq_f32(vdupq_n_f32(0.344_136), cbv)),
            vmulq_f32(vdupq_n_f32(0.714_136), crv),
        );
        // b = yy + 1.772 cb
        let b = vaddq_f32(yy, vmulq_f32(vdupq_n_f32(1.772), cbv));
        let r = vmaxq_f32(vminq_f32(vdivq_f32(r, inv), one), zero);
        let g = vmaxq_f32(vminq_f32(vdivq_f32(g, inv), one), zero);
        let b = vmaxq_f32(vminq_f32(vdivq_f32(b, inv), one), zero);
        vst3q_f32(rgb.as_mut_ptr().add(i * 12), float32x4x3_t(r, g, b));
    }
    n / 4 * 4
}

//! Compression codecs: the baseline JPEG implementation and (in `crate::inr`)
//! the INR weight format. Kept separate from `inr` because JPEG operates on
//! pixels while INR "encoding" is neural-network training on the fog node.

pub mod jpeg;
pub mod kernels;

//! JPEG quantization tables with IJG-style quality scaling.

/// Annex-K luminance base table.
pub const LUMA_BASE: [u16; 64] = [
    16, 11, 10, 16, 24, 40, 51, 61, //
    12, 12, 14, 19, 26, 58, 60, 55, //
    14, 13, 16, 24, 40, 57, 69, 56, //
    14, 17, 22, 29, 51, 87, 80, 62, //
    18, 22, 37, 56, 68, 109, 103, 77, //
    24, 35, 55, 64, 81, 104, 113, 92, //
    49, 64, 78, 87, 103, 121, 120, 101, //
    72, 92, 95, 98, 112, 100, 103, 99,
];

/// Annex-K chrominance base table.
pub const CHROMA_BASE: [u16; 64] = [
    17, 18, 24, 47, 99, 99, 99, 99, //
    18, 21, 26, 66, 99, 99, 99, 99, //
    24, 26, 56, 99, 99, 99, 99, 99, //
    47, 66, 99, 99, 99, 99, 99, 99, //
    99, 99, 99, 99, 99, 99, 99, 99, //
    99, 99, 99, 99, 99, 99, 99, 99, //
    99, 99, 99, 99, 99, 99, 99, 99, //
    99, 99, 99, 99, 99, 99, 99, 99,
];

/// Scale a base table by JPEG quality `q ∈ [1, 100]` (IJG formula).
pub fn scaled_table(base: &[u16; 64], quality: u8) -> [u16; 64] {
    let q = quality.clamp(1, 100) as i32;
    let scale = if q < 50 { 5000 / q } else { 200 - 2 * q };
    let mut out = [0u16; 64];
    for i in 0..64 {
        let v = (base[i] as i32 * scale + 50) / 100;
        out[i] = v.clamp(1, 255) as u16;
    }
    out
}

/// Quantize DCT coefficients: `round(coef / table)`.
pub fn quantize(coef: &[f32; 64], table: &[u16; 64]) -> [i16; 64] {
    let mut out = [0i16; 64];
    for i in 0..64 {
        out[i] = (coef[i] / table[i] as f32).round() as i16;
    }
    out
}

/// Dequantize: `q * table`.
pub fn dequantize(q: &[i16; 64], table: &[u16; 64]) -> [f32; 64] {
    let mut out = [0.0f32; 64];
    for i in 0..64 {
        out[i] = q[i] as f32 * table[i] as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quality_50_is_base() {
        assert_eq!(scaled_table(&LUMA_BASE, 50), LUMA_BASE);
    }

    #[test]
    fn quality_monotone() {
        // Higher quality → smaller divisors → finer quantization.
        let q30 = scaled_table(&LUMA_BASE, 30);
        let q80 = scaled_table(&LUMA_BASE, 80);
        for i in 0..64 {
            assert!(q80[i] <= q30[i]);
        }
    }

    #[test]
    fn quality_100_near_lossless() {
        let q100 = scaled_table(&LUMA_BASE, 100);
        assert!(q100.iter().all(|&v| v == 1));
    }

    #[test]
    fn quant_dequant_error_bounded() {
        let table = scaled_table(&LUMA_BASE, 50);
        let mut coef = [0.0f32; 64];
        for (i, c) in coef.iter_mut().enumerate() {
            *c = (i as f32 - 32.0) * 7.3;
        }
        let q = quantize(&coef, &table);
        let d = dequantize(&q, &table);
        for i in 0..64 {
            assert!((coef[i] - d[i]).abs() <= table[i] as f32 / 2.0 + 1e-3);
        }
    }
}

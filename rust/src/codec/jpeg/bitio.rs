//! MSB-first bit writer/reader for the entropy-coded segment.
//!
//! The production [`BitWriter`] packs bits into a `u64` accumulator and
//! flushes whole bytes, so a batched Huffman emission (`code || magnitude
//! bits` in one call, see `coder::write_component`) costs one shift/or per
//! symbol instead of one branch per bit. The original per-bit writer is
//! retained verbatim as [`ReferenceBitWriter`]: it is the exact-match
//! oracle the tests diff against, byte for byte.

/// Append-only MSB-first bit writer with a 64-bit accumulator.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,   // valid bits live in acc[0, nbits); higher bits are garbage
    nbits: u32, // always < 8 between calls
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `value`, MSB first. `n ≤ 32`.
    #[inline]
    pub fn write(&mut self, value: u32, n: u8) {
        self.write_u64(value as u64, n);
    }

    /// Write the low `n` bits of `value`, MSB first. `n ≤ 57` so that the
    /// accumulator (at most 7 residual bits between calls) cannot overflow.
    /// Wide enough for a full Huffman code plus magnitude bits in one call.
    #[inline]
    pub fn write_u64(&mut self, value: u64, n: u8) {
        debug_assert!(n <= 57);
        if n == 0 {
            return;
        }
        let v = value & (u64::MAX >> (64 - n as u32));
        self.acc = (self.acc << n) | v;
        self.nbits += n as u32;
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.buf.push((self.acc >> self.nbits) as u8);
        }
    }

    /// Pad with 1-bits to a byte boundary and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            let pad = 8 - self.nbits;
            let byte = ((self.acc << pad) | ((1u64 << pad) - 1)) as u8;
            self.buf.push(byte);
        }
        self.buf
    }

    /// Bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }
}

/// The original per-bit writer, kept verbatim as the exactness oracle for
/// [`BitWriter`]. Not used on the encode hot path.
#[derive(Debug, Default)]
pub struct ReferenceBitWriter {
    buf: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl ReferenceBitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `value`, MSB first. `n ≤ 32`.
    pub fn write(&mut self, value: u32, n: u8) {
        debug_assert!(n <= 32);
        for i in (0..n).rev() {
            let bit = ((value >> i) & 1) as u8;
            self.cur = (self.cur << 1) | bit;
            self.nbits += 1;
            if self.nbits == 8 {
                self.buf.push(self.cur);
                self.cur = 0;
                self.nbits = 0;
            }
        }
    }

    /// Pad with 1-bits to a byte boundary and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            let pad = 8 - self.nbits;
            self.cur = (self.cur << pad) | ((1u16 << pad) as u8).wrapping_sub(1);
            self.buf.push(self.cur);
        }
        self.buf
    }

    /// Bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }
}

/// MSB-first bit reader.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read one bit; `None` at end of input.
    #[inline]
    pub fn bit(&mut self) -> Option<u8> {
        let byte = self.buf.get(self.pos / 8)?;
        let bit = (byte >> (7 - (self.pos % 8))) & 1;
        self.pos += 1;
        Some(bit)
    }

    /// Read `n` bits MSB-first into a u32.
    pub fn bits(&mut self, n: u8) -> Option<u32> {
        let mut v = 0u32;
        for _ in 0..n {
            v = (v << 1) | self.bit()? as u32;
        }
        Some(v)
    }

    pub fn bit_pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn write_read_roundtrip() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(0xFF, 8);
        w.write(0, 1);
        w.write(0b110011, 6);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.bits(3), Some(0b101));
        assert_eq!(r.bits(8), Some(0xFF));
        assert_eq!(r.bits(1), Some(0));
        assert_eq!(r.bits(6), Some(0b110011));
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        w.write(1, 1);
        assert_eq!(w.bit_len(), 1);
        w.write(0xABCD, 16);
        assert_eq!(w.bit_len(), 17);
    }

    #[test]
    fn reader_ends_cleanly() {
        let mut w = BitWriter::new();
        w.write(0b10, 2);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        let _ = r.bits(8);
        assert_eq!(r.bits(8), None);
    }

    #[test]
    fn zero_width_write_is_noop() {
        let mut w = BitWriter::new();
        w.write(123, 0);
        assert_eq!(w.bit_len(), 0);
        assert!(w.finish().is_empty());
    }

    #[test]
    fn high_bits_above_n_are_masked() {
        let mut w = BitWriter::new();
        w.write(0xFFFF_FFFF, 3); // only the low 3 bits count
        let mut o = ReferenceBitWriter::new();
        o.write(0xFFFF_FFFF, 3);
        assert_eq!(w.finish(), o.finish());
    }

    /// The accumulator writer must match the per-bit oracle byte-for-byte
    /// on random streams of (value, width) pairs, including widths > 32
    /// via `write_u64` split into two oracle writes.
    #[test]
    fn matches_reference_writer_exactly() {
        let mut rng = Pcg32::seeded(0x1b17);
        for _ in 0..200 {
            let mut w = BitWriter::new();
            let mut o = ReferenceBitWriter::new();
            let n_ops = 1 + (rng.next_u32() % 64) as usize;
            for _ in 0..n_ops {
                let n = (rng.next_u32() % 58) as u8; // 0..=57
                let v =
                    if n == 0 { 0 } else { rng.next_u64() & (u64::MAX >> (64 - n as u32)) };
                w.write_u64(v, n);
                if n > 32 {
                    o.write((v >> 32) as u32, n - 32);
                    o.write(v as u32, 32);
                } else {
                    o.write(v as u32, n);
                }
            }
            assert_eq!(w.bit_len(), o.bit_len());
            assert_eq!(w.finish(), o.finish());
        }
    }

    /// A batched `code || magnitude` emission equals the two-call form.
    #[test]
    fn batched_symbol_equals_split_writes() {
        let mut rng = Pcg32::seeded(7);
        let mut w = BitWriter::new();
        let mut o = ReferenceBitWriter::new();
        for _ in 0..500 {
            let l = 1 + (rng.next_u32() % 16) as u8; // code length 1..=16
            let cat = (rng.next_u32() % 17) as u8; // category 0..=16
            let code = rng.next_u32() & ((1u32 << l) - 1);
            let bits = if cat == 0 { 0 } else { rng.next_u32() & ((1u32 << cat) - 1) };
            w.write_u64(((code as u64) << cat) | bits as u64, l + cat);
            o.write(code, l);
            o.write(bits, cat);
        }
        assert_eq!(w.finish(), o.finish());
    }
}

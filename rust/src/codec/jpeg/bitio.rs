//! MSB-first bit writer/reader for the entropy-coded segment.

/// Append-only MSB-first bit writer.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `value`, MSB first. `n ≤ 32`.
    pub fn write(&mut self, value: u32, n: u8) {
        debug_assert!(n <= 32);
        for i in (0..n).rev() {
            let bit = ((value >> i) & 1) as u8;
            self.cur = (self.cur << 1) | bit;
            self.nbits += 1;
            if self.nbits == 8 {
                self.buf.push(self.cur);
                self.cur = 0;
                self.nbits = 0;
            }
        }
    }

    /// Pad with 1-bits to a byte boundary and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            let pad = 8 - self.nbits;
            self.cur = (self.cur << pad) | ((1u16 << pad) as u8).wrapping_sub(1);
            self.buf.push(self.cur);
        }
        self.buf
    }

    /// Bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }
}

/// MSB-first bit reader.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read one bit; `None` at end of input.
    #[inline]
    pub fn bit(&mut self) -> Option<u8> {
        let byte = self.buf.get(self.pos / 8)?;
        let bit = (byte >> (7 - (self.pos % 8))) & 1;
        self.pos += 1;
        Some(bit)
    }

    /// Read `n` bits MSB-first into a u32.
    pub fn bits(&mut self, n: u8) -> Option<u32> {
        let mut v = 0u32;
        for _ in 0..n {
            v = (v << 1) | self.bit()? as u32;
        }
        Some(v)
    }

    pub fn bit_pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(0xFF, 8);
        w.write(0, 1);
        w.write(0b110011, 6);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.bits(3), Some(0b101));
        assert_eq!(r.bits(8), Some(0xFF));
        assert_eq!(r.bits(1), Some(0));
        assert_eq!(r.bits(6), Some(0b110011));
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        w.write(1, 1);
        assert_eq!(w.bit_len(), 1);
        w.write(0xABCD, 16);
        assert_eq!(w.bit_len(), 17);
    }

    #[test]
    fn reader_ends_cleanly() {
        let mut w = BitWriter::new();
        w.write(0b10, 2);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        let _ = r.bits(8);
        assert_eq!(r.bits(8), None);
    }

    #[test]
    fn zero_width_write_is_noop() {
        let mut w = BitWriter::new();
        w.write(123, 0);
        assert_eq!(w.bit_len(), 0);
    }
}

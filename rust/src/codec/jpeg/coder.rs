//! Baseline JPEG-style encoder/decoder.
//!
//! Standard JPEG coding pipeline — RGB→YCbCr, 4:2:0 chroma subsampling,
//! 8×8 DCT, quality-scaled quantization, zigzag, DPCM-coded DC +
//! run/size-coded AC, per-image optimized canonical Huffman — wrapped in a
//! simple container (`RJPG`) instead of JFIF markers. The *rate/quality
//! behaviour* matches baseline JPEG (what the paper's Fig 9 sweeps);
//! interchange with libjpeg is a non-goal.

use anyhow::{bail, Context, Result};

use super::bitio::{BitReader, BitWriter};
use super::color::{subsample_420, upsample_420, Plane};
use super::huffman::{HuffDecoder, HuffTable, MAX_CODE_LEN};
use super::quant::{dequantize, quantize, scaled_table, CHROMA_BASE, LUMA_BASE};
use super::zigzag::{from_zigzag, to_zigzag};
// DCT and color conversion go through the runtime-dispatched SIMD kernels
// (bit-identical to the scalar code in `dct`/`color`, see codec::kernels).
use crate::codec::kernels::{fdct8x8, idct8x8, rgb_to_ycbcr, ycbcr_to_rgb};
use crate::data::ImageRGB;

const MAGIC: &[u8; 4] = b"RJPG";
const VERSION: u8 = 1;

/// Encode an image at JPEG quality `quality ∈ [1, 100]`.
pub fn encode(img: &ImageRGB, quality: u8) -> Vec<u8> {
    let (yp, cbp, crp) = rgb_to_ycbcr(img.width, img.height, &img.data);
    let cb = subsample_420(&cbp);
    let cr = subsample_420(&crp);
    let lq = scaled_table(&LUMA_BASE, quality);
    let cq = scaled_table(&CHROMA_BASE, quality);

    // Quantized zigzag blocks per component.
    let yb = plane_to_blocks(&yp, &lq);
    let cbb = plane_to_blocks(&cb, &cq);
    let crb = plane_to_blocks(&cr, &cq);

    // First pass: count symbol frequencies for optimized tables.
    let mut dc_l = vec![0u64; 17];
    let mut ac_l = vec![0u64; 256];
    let mut dc_c = vec![0u64; 17];
    let mut ac_c = vec![0u64; 256];
    count_component(&yb, &mut dc_l, &mut ac_l);
    count_component(&cbb, &mut dc_c, &mut ac_c);
    count_component(&crb, &mut dc_c, &mut ac_c);

    let t_dc_l = HuffTable::from_frequencies(&dc_l);
    let t_ac_l = HuffTable::from_frequencies(&ac_l);
    let t_dc_c = HuffTable::from_frequencies(&dc_c);
    let t_ac_c = HuffTable::from_frequencies(&ac_c);

    // Second pass: entropy-code.
    let mut w = BitWriter::new();
    write_component(&yb, &t_dc_l, &t_ac_l, &mut w);
    write_component(&cbb, &t_dc_c, &t_ac_c, &mut w);
    write_component(&crb, &t_dc_c, &t_ac_c, &mut w);
    let scan = w.finish();

    // Container.
    let mut out = Vec::with_capacity(scan.len() + 256);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(img.width as u16).to_le_bytes());
    out.extend_from_slice(&(img.height as u16).to_le_bytes());
    out.push(quality);
    for t in [&t_dc_l, &t_ac_l, &t_dc_c, &t_ac_c] {
        out.extend_from_slice(&t.counts);
        out.push(t.symbols.len() as u8); // ≤ 255 symbols used in practice
        out.extend_from_slice(&t.symbols);
    }
    out.extend_from_slice(&(scan.len() as u32).to_le_bytes());
    out.extend_from_slice(&scan);
    out
}

/// Decode an `RJPG` byte stream.
pub fn decode(bytes: &[u8]) -> Result<ImageRGB> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > bytes.len() {
            bail!("truncated RJPG at byte {}", *pos);
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    if take(&mut pos, 4)? != MAGIC {
        bail!("bad magic");
    }
    let version = take(&mut pos, 1)?[0];
    if version != VERSION {
        bail!("unsupported RJPG version {version}");
    }
    let width = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap()) as usize;
    let height = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap()) as usize;
    let quality = take(&mut pos, 1)?[0];
    if width == 0 || height == 0 {
        bail!("zero dimension");
    }

    let mut tables = Vec::with_capacity(4);
    for _ in 0..4 {
        let counts: [u8; MAX_CODE_LEN] =
            take(&mut pos, MAX_CODE_LEN)?.try_into().unwrap();
        let nsym = take(&mut pos, 1)?[0] as usize;
        let symbols = take(&mut pos, nsym)?.to_vec();
        let total: usize = counts.iter().map(|&c| c as usize).sum();
        if total != symbols.len() {
            bail!("huffman spec mismatch");
        }
        tables.push(HuffTable::from_spec(counts, symbols));
    }
    let scan_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let scan = take(&mut pos, scan_len)?;

    let lq = scaled_table(&LUMA_BASE, quality);
    let cq = scaled_table(&CHROMA_BASE, quality);

    let (cw, ch) = (width.div_ceil(2), height.div_ceil(2));
    let d_dc_l = tables[0].decoder();
    let d_ac_l = tables[1].decoder();
    let d_dc_c = tables[2].decoder();
    let d_ac_c = tables[3].decoder();

    let mut r = BitReader::new(scan);
    let yp = read_component(&mut r, width, height, &d_dc_l, &d_ac_l, &lq)
        .context("luma scan")?;
    let cbp = read_component(&mut r, cw, ch, &d_dc_c, &d_ac_c, &cq)
        .context("cb scan")?;
    let crp = read_component(&mut r, cw, ch, &d_dc_c, &d_ac_c, &cq)
        .context("cr scan")?;

    let cb = upsample_420(&cbp, width, height);
    let cr = upsample_420(&crp, width, height);
    let rgb = ycbcr_to_rgb(&yp, &cb, &cr);
    Ok(ImageRGB { width, height, data: rgb })
}

/// Split a plane into quantized zigzag 8×8 blocks (raster order, edge
/// pixels replicated).
fn plane_to_blocks(p: &Plane, table: &[u16; 64]) -> Vec<[i16; 64]> {
    let bw = p.width.div_ceil(8);
    let bh = p.height.div_ceil(8);
    let mut blocks = Vec::with_capacity(bw * bh);
    for by in 0..bh {
        for bx in 0..bw {
            let mut block = [0.0f32; 64];
            for dy in 0..8 {
                for dx in 0..8 {
                    block[dy * 8 + dx] = p
                        .at_clamped((bx * 8 + dx) as isize, (by * 8 + dy) as isize)
                        - 128.0; // level shift
                }
            }
            let coef = fdct8x8(&block);
            blocks.push(to_zigzag(&quantize(&coef, table)));
        }
    }
    blocks
}

/// Rebuild a plane from quantized zigzag blocks.
fn blocks_to_plane(blocks: &[[i16; 64]], w: usize, h: usize, table: &[u16; 64]) -> Plane {
    let bw = w.div_ceil(8);
    let mut p = Plane::zeros(w, h);
    for (bi, zz) in blocks.iter().enumerate() {
        let bx = bi % bw;
        let by = bi / bw;
        let pix = idct8x8(&dequantize(&from_zigzag(zz), table));
        for dy in 0..8 {
            let y = by * 8 + dy;
            if y >= h {
                break;
            }
            for dx in 0..8 {
                let x = bx * 8 + dx;
                if x >= w {
                    break;
                }
                p.set(x, y, pix[dy * 8 + dx] + 128.0);
            }
        }
    }
    p
}

/// Magnitude category (bit length) of a coefficient, JPEG style.
#[inline]
fn category(v: i32) -> u8 {
    (32 - (v.unsigned_abs()).leading_zeros()) as u8
}

/// JPEG magnitude bits: positive as-is; negative as one's complement.
#[inline]
fn magnitude_bits(v: i32, cat: u8) -> u32 {
    if v >= 0 {
        v as u32
    } else {
        (v + (1 << cat) - 1) as u32
    }
}

#[inline]
fn extend_magnitude(bits: u32, cat: u8) -> i32 {
    if cat == 0 {
        return 0;
    }
    let half = 1i32 << (cat - 1);
    if (bits as i32) < half {
        bits as i32 - (1 << cat) + 1
    } else {
        bits as i32
    }
}

/// Iterate the (dc_symbol, ac_symbols) stream of one component, feeding the
/// visitor; shared by the frequency-count and entropy-write passes.
fn code_component<FD, FA>(blocks: &[[i16; 64]], mut on_dc: FD, mut on_ac: FA)
where
    FD: FnMut(u8, u32),
    FA: FnMut(u8, u8, u32),
{
    let mut prev_dc = 0i32;
    for zz in blocks {
        let dc = zz[0] as i32;
        let diff = dc - prev_dc;
        prev_dc = dc;
        let cat = category(diff);
        on_dc(cat, magnitude_bits(diff, cat));
        let mut run = 0u8;
        for &c in &zz[1..] {
            if c == 0 {
                run += 1;
                continue;
            }
            while run >= 16 {
                on_ac(0xF0, 0, 0); // ZRL
                run -= 16;
            }
            let cat = category(c as i32);
            on_ac((run << 4) | cat, cat, magnitude_bits(c as i32, cat));
            run = 0;
        }
        if run > 0 {
            on_ac(0x00, 0, 0); // EOB
        }
    }
}

fn count_component(blocks: &[[i16; 64]], dc: &mut [u64], ac: &mut [u64]) {
    code_component(
        blocks,
        |cat, _| dc[cat as usize] += 1,
        |sym, _, _| ac[sym as usize] += 1,
    );
}

fn write_component(blocks: &[[i16; 64]], t_dc: &HuffTable, t_ac: &HuffTable, w: &mut BitWriter) {
    // Batched emission: `code ‖ magnitude` packed into one u64 write per
    // symbol (≤ 16 code bits + ≤ 17 magnitude bits), instead of two
    // per-symbol calls into the bit writer.
    let w = std::cell::RefCell::new(w);
    code_component(
        blocks,
        |cat, bits| {
            let (c, l) = t_dc.encode(cat);
            w.borrow_mut().write_u64(((c as u64) << cat) | bits as u64, l + cat);
        },
        |sym, cat, bits| {
            let (c, l) = t_ac.encode(sym);
            w.borrow_mut().write_u64(((c as u64) << cat) | bits as u64, l + cat);
        },
    );
}

fn read_component(
    r: &mut BitReader<'_>,
    w: usize,
    h: usize,
    d_dc: &HuffDecoder,
    d_ac: &HuffDecoder,
    table: &[u16; 64],
) -> Result<Plane> {
    let bw = w.div_ceil(8);
    let bh = h.div_ceil(8);
    let mut blocks = Vec::with_capacity(bw * bh);
    let mut prev_dc = 0i32;
    for _ in 0..bw * bh {
        let mut zz = [0i16; 64];
        let cat = d_dc.decode(r).context("dc symbol")?;
        let bits = r.bits(cat).context("dc magnitude")?;
        let diff = extend_magnitude(bits, cat);
        prev_dc += diff;
        zz[0] = prev_dc as i16;
        let mut k = 1usize;
        while k < 64 {
            let sym = d_ac.decode(r).context("ac symbol")?;
            if sym == 0x00 {
                break; // EOB
            }
            if sym == 0xF0 {
                k += 16;
                continue;
            }
            let run = (sym >> 4) as usize;
            let cat = sym & 0x0F;
            k += run;
            if k >= 64 {
                bail!("AC run overflow");
            }
            let bits = r.bits(cat).context("ac magnitude")?;
            zz[k] = extend_magnitude(bits, cat) as i16;
            k += 1;
        }
        blocks.push(zz);
    }
    Ok(blocks_to_plane(&blocks, w, h, table))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_sequence, Profile};
    use crate::metrics::psnr::psnr;

    #[test]
    fn category_and_magnitude() {
        assert_eq!(category(0), 0);
        assert_eq!(category(1), 1);
        assert_eq!(category(-1), 1);
        assert_eq!(category(255), 8);
        assert_eq!(category(-255), 8);
        for v in [-300i32, -17, -1, 0, 1, 9, 255, 1023] {
            let c = category(v);
            assert_eq!(extend_magnitude(magnitude_bits(v, c), c), v, "v={v}");
        }
    }

    #[test]
    fn roundtrip_synthetic_frame_high_quality() {
        let seq = generate_sequence(Profile::Uav123, 5, 0);
        let img = &seq.frames[0];
        let bytes = encode(img, 90);
        let dec = decode(&bytes).unwrap();
        assert_eq!((dec.width, dec.height), (img.width, img.height));
        let p = psnr(img, &dec);
        assert!(p > 28.0, "psnr={p}");
    }

    #[test]
    fn quality_controls_size_and_psnr() {
        let seq = generate_sequence(Profile::Otb100, 9, 1);
        let img = &seq.frames[0];
        let lo = encode(img, 20);
        let hi = encode(img, 90);
        assert!(lo.len() < hi.len(), "{} vs {}", lo.len(), hi.len());
        let p_lo = psnr(img, &decode(&lo).unwrap());
        let p_hi = psnr(img, &decode(&hi).unwrap());
        assert!(p_hi > p_lo, "{p_hi} vs {p_lo}");
    }

    #[test]
    fn compresses_below_raw() {
        let seq = generate_sequence(Profile::DacSdc, 2, 0);
        let img = &seq.frames[0];
        let raw = img.pixels() * 3; // 8-bit raw
        let enc = encode(img, 75);
        assert!(enc.len() < raw, "{} vs raw {}", enc.len(), raw);
    }

    #[test]
    fn odd_dimensions_roundtrip() {
        let img = ImageRGB::from_fn(37, 23, |x, y| {
            [
                x as f32 / 37.0,
                y as f32 / 23.0,
                0.5 + 0.3 * ((x as f32 * 0.4).sin() * (y as f32 * 0.3).cos()),
            ]
        });
        let dec = decode(&encode(&img, 80)).unwrap();
        assert_eq!((dec.width, dec.height), (37, 23));
        assert!(psnr(&img, &dec) > 25.0);
    }

    #[test]
    fn rejects_corrupt_streams() {
        let img = ImageRGB::from_fn(16, 16, |x, y| [x as f32 / 16.0, y as f32 / 16.0, 0.5]);
        let bytes = encode(&img, 50);
        assert!(decode(&bytes[..10]).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn constant_image_tiny_encoding() {
        let img = ImageRGB::from_fn(64, 64, |_, _| [0.5, 0.5, 0.5]);
        let bytes = encode(&img, 75);
        // All-zero ACs + tiny DC stream: should be far below 1 bpp.
        assert!(bytes.len() < 800, "len={}", bytes.len());
        let dec = decode(&bytes).unwrap();
        assert!(psnr(&img, &dec) > 40.0);
    }

    #[test]
    fn property_random_images_roundtrip() {
        crate::util::propcheck::check_seeded("rjpg-roundtrip", 77, 16, |rng| {
            let w = 8 + rng.below_usize(40);
            let h = 8 + rng.below_usize(40);
            let img = ImageRGB {
                width: w,
                height: h,
                data: (0..w * h * 3).map(|_| rng.f32()).collect(),
            };
            let q = 10 + rng.below(90) as u8;
            let dec = decode(&encode(&img, q)).unwrap();
            assert_eq!((dec.width, dec.height), (w, h));
            // Even at low quality decode must stay in range and finite.
            assert!(dec.data.iter().all(|v| v.is_finite() && (0.0..=1.0).contains(v)));
        });
    }
}

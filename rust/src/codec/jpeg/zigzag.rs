//! Zigzag scan order for 8×8 coefficient blocks.

/// `ZIGZAG[k]` is the row-major index of the k-th coefficient in zigzag
/// order (standard JPEG scan).
pub const ZIGZAG: [usize; 64] = [
    0, 1, 8, 16, 9, 2, 3, 10, //
    17, 24, 32, 25, 18, 11, 4, 5, //
    12, 19, 26, 33, 40, 48, 41, 34, //
    27, 20, 13, 6, 7, 14, 21, 28, //
    35, 42, 49, 56, 57, 50, 43, 36, //
    29, 22, 15, 23, 30, 37, 44, 51, //
    58, 59, 52, 45, 38, 31, 39, 46, //
    53, 60, 61, 54, 47, 55, 62, 63,
];

/// Reorder a row-major block into zigzag order.
pub fn to_zigzag(block: &[i16; 64]) -> [i16; 64] {
    let mut out = [0i16; 64];
    for (k, &idx) in ZIGZAG.iter().enumerate() {
        out[k] = block[idx];
    }
    out
}

/// Inverse: zigzag order back to row-major.
pub fn from_zigzag(zz: &[i16; 64]) -> [i16; 64] {
    let mut out = [0i16; 64];
    for (k, &idx) in ZIGZAG.iter().enumerate() {
        out[idx] = zz[k];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_is_permutation() {
        let mut seen = [false; 64];
        for &i in &ZIGZAG {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zigzag_starts_dc_then_first_two_acs() {
        assert_eq!(ZIGZAG[0], 0);
        assert_eq!(ZIGZAG[1], 1); // (0,1)
        assert_eq!(ZIGZAG[2], 8); // (1,0)
    }

    #[test]
    fn roundtrip() {
        let mut b = [0i16; 64];
        for (i, v) in b.iter_mut().enumerate() {
            *v = i as i16 * 3 - 50;
        }
        assert_eq!(from_zigzag(&to_zigzag(&b)), b);
    }
}

//! RGB ↔ YCbCr (BT.601 full-range) conversion and 4:2:0 chroma
//! subsampling — the front half of the baseline JPEG codec.

/// One image plane (single channel, f32, nominal range [0, 255]).
#[derive(Debug, Clone)]
pub struct Plane {
    pub width: usize,
    pub height: usize,
    pub data: Vec<f32>,
}

impl Plane {
    pub fn zeros(width: usize, height: usize) -> Self {
        Plane { width, height, data: vec![0.0; width * height] }
    }

    #[inline]
    pub fn at(&self, x: usize, y: usize) -> f32 {
        self.data[y * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: f32) {
        self.data[y * self.width + x] = v;
    }

    /// Clamped access (edge replication) for block extraction at borders.
    #[inline]
    pub fn at_clamped(&self, x: isize, y: isize) -> f32 {
        let x = x.clamp(0, self.width as isize - 1) as usize;
        let y = y.clamp(0, self.height as isize - 1) as usize;
        self.at(x, y)
    }
}

/// Convert interleaved RGB f32 `[0,1]` to Y, Cb, Cr planes in `[0,255]`.
pub fn rgb_to_ycbcr(width: usize, height: usize, rgb01: &[f32]) -> (Plane, Plane, Plane) {
    assert_eq!(rgb01.len(), width * height * 3);
    let mut y = Plane::zeros(width, height);
    let mut cb = Plane::zeros(width, height);
    let mut cr = Plane::zeros(width, height);
    for i in 0..width * height {
        let r = rgb01[3 * i] * 255.0;
        let g = rgb01[3 * i + 1] * 255.0;
        let b = rgb01[3 * i + 2] * 255.0;
        y.data[i] = 0.299 * r + 0.587 * g + 0.114 * b;
        cb.data[i] = 128.0 - 0.168_736 * r - 0.331_264 * g + 0.5 * b;
        cr.data[i] = 128.0 + 0.5 * r - 0.418_688 * g - 0.081_312 * b;
    }
    (y, cb, cr)
}

/// Convert Y, Cb, Cr planes (`[0,255]`, same size) back to interleaved RGB
/// f32 `[0,1]`.
pub fn ycbcr_to_rgb(y: &Plane, cb: &Plane, cr: &Plane) -> Vec<f32> {
    assert_eq!((y.width, y.height), (cb.width, cb.height));
    assert_eq!((y.width, y.height), (cr.width, cr.height));
    let n = y.width * y.height;
    let mut rgb = vec![0.0f32; n * 3];
    for i in 0..n {
        let yy = y.data[i];
        let cbv = cb.data[i] - 128.0;
        let crv = cr.data[i] - 128.0;
        let r = yy + 1.402 * crv;
        let g = yy - 0.344_136 * cbv - 0.714_136 * crv;
        let b = yy + 1.772 * cbv;
        rgb[3 * i] = (r / 255.0).clamp(0.0, 1.0);
        rgb[3 * i + 1] = (g / 255.0).clamp(0.0, 1.0);
        rgb[3 * i + 2] = (b / 255.0).clamp(0.0, 1.0);
    }
    rgb
}

/// 4:2:0 subsample: average each 2×2 block (odd edges replicate).
pub fn subsample_420(p: &Plane) -> Plane {
    let w2 = p.width.div_ceil(2);
    let h2 = p.height.div_ceil(2);
    let mut out = Plane::zeros(w2, h2);
    for y in 0..h2 {
        for x in 0..w2 {
            let mut acc = 0.0;
            for dy in 0..2 {
                for dx in 0..2 {
                    acc += p.at_clamped((2 * x + dx) as isize, (2 * y + dy) as isize);
                }
            }
            out.set(x, y, acc / 4.0);
        }
    }
    out
}

/// Upsample a 4:2:0 plane back to `(w, h)` by bilinear interpolation.
pub fn upsample_420(p: &Plane, w: usize, h: usize) -> Plane {
    let mut out = Plane::zeros(w, h);
    for y in 0..h {
        for x in 0..w {
            // Sample position in subsampled grid (center-aligned).
            let sx = (x as f32 - 0.5) / 2.0;
            let sy = (y as f32 - 0.5) / 2.0;
            let x0 = sx.floor() as isize;
            let y0 = sy.floor() as isize;
            let fx = sx - x0 as f32;
            let fy = sy - y0 as f32;
            let v00 = p.at_clamped(x0, y0);
            let v10 = p.at_clamped(x0 + 1, y0);
            let v01 = p.at_clamped(x0, y0 + 1);
            let v11 = p.at_clamped(x0 + 1, y0 + 1);
            let v = v00 * (1.0 - fx) * (1.0 - fy)
                + v10 * fx * (1.0 - fy)
                + v01 * (1.0 - fx) * fy
                + v11 * fx * fy;
            out.set(x, y, v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn rgb_ycbcr_roundtrip() {
        let mut rng = Pcg32::seeded(4);
        let (w, h) = (16, 8);
        let rgb: Vec<f32> = (0..w * h * 3).map(|_| rng.f32()).collect();
        let (y, cb, cr) = rgb_to_ycbcr(w, h, &rgb);
        let back = ycbcr_to_rgb(&y, &cb, &cr);
        for (a, b) in rgb.iter().zip(&back) {
            assert!((a - b).abs() < 2.0 / 255.0, "{a} vs {b}");
        }
    }

    #[test]
    fn gray_has_neutral_chroma() {
        let rgb = vec![0.5f32; 4 * 4 * 3];
        let (_, cb, cr) = rgb_to_ycbcr(4, 4, &rgb);
        for i in 0..16 {
            assert!((cb.data[i] - 128.0).abs() < 0.5);
            assert!((cr.data[i] - 128.0).abs() < 0.5);
        }
    }

    #[test]
    fn subsample_upsample_constant_plane() {
        let mut p = Plane::zeros(10, 6);
        p.data.fill(100.0);
        let s = subsample_420(&p);
        assert_eq!((s.width, s.height), (5, 3));
        let u = upsample_420(&s, 10, 6);
        for &v in &u.data {
            assert!((v - 100.0).abs() < 1e-3);
        }
    }

    #[test]
    fn subsample_handles_odd_sizes() {
        let mut p = Plane::zeros(5, 5);
        for (i, v) in p.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let s = subsample_420(&p);
        assert_eq!((s.width, s.height), (3, 3));
        let u = upsample_420(&s, 5, 5);
        assert_eq!((u.width, u.height), (5, 5));
    }

    #[test]
    fn subsample_smooth_gradient_small_error() {
        let mut p = Plane::zeros(32, 32);
        for y in 0..32 {
            for x in 0..32 {
                p.set(x, y, (x + y) as f32 * 2.0);
            }
        }
        let u = upsample_420(&subsample_420(&p), 32, 32);
        let mut max_err: f32 = 0.0;
        for y in 2..30 {
            for x in 2..30 {
                max_err = max_err.max((u.at(x, y) - p.at(x, y)).abs());
            }
        }
        assert!(max_err < 3.0, "max_err={max_err}");
    }
}

//! 8×8 forward/inverse DCT-II for the baseline JPEG codec.
//!
//! Two implementations: a reference O(n⁴) transform (kept for tests) and a
//! separable row/column fast path with a precomputed 8×8 cosine basis —
//! the codec hot loop (see EXPERIMENTS.md §Perf for the before/after).

/// Precomputed `c[u][x] = alpha(u) * cos((2x+1) u π / 16)` basis.
struct Basis {
    c: [[f32; 8]; 8],
}

impl Basis {
    const fn alpha(u: usize) -> f32 {
        if u == 0 {
            0.353_553_39 // 1/sqrt(8)
        } else {
            0.5 // sqrt(2/8)
        }
    }

    fn new() -> Self {
        let mut c = [[0.0f32; 8]; 8];
        for (u, row) in c.iter_mut().enumerate() {
            for (x, v) in row.iter_mut().enumerate() {
                *v = Self::alpha(u)
                    * ((2 * x + 1) as f32 * u as f32 * std::f32::consts::PI / 16.0).cos();
            }
        }
        Basis { c }
    }
}

fn basis() -> &'static Basis {
    use std::sync::OnceLock;
    static B: OnceLock<Basis> = OnceLock::new();
    B.get_or_init(Basis::new)
}

/// The precomputed cosine basis `c[u][x]`, shared with `codec::kernels` so
/// the SIMD paths use bit-identical coefficients.
pub(crate) fn basis_c() -> &'static [[f32; 8]; 8] {
    &basis().c
}

/// Forward 8×8 DCT-II (separable fast path). `block` is row-major.
pub fn fdct8x8(block: &[f32; 64]) -> [f32; 64] {
    let b = basis();
    // Rows first: tmp[y][u] = Σ_x block[y][x] c[u][x]
    let mut tmp = [0.0f32; 64];
    for y in 0..8 {
        for u in 0..8 {
            let mut acc = 0.0;
            for x in 0..8 {
                acc += block[y * 8 + x] * b.c[u][x];
            }
            tmp[y * 8 + u] = acc;
        }
    }
    // Columns: out[v][u] = Σ_y tmp[y][u] c[v][y]
    let mut out = [0.0f32; 64];
    for v in 0..8 {
        for u in 0..8 {
            let mut acc = 0.0;
            for y in 0..8 {
                acc += tmp[y * 8 + u] * b.c[v][y];
            }
            out[v * 8 + u] = acc;
        }
    }
    out
}

/// Inverse 8×8 DCT (separable).
pub fn idct8x8(coef: &[f32; 64]) -> [f32; 64] {
    let b = basis();
    // Columns first: tmp[y][u] = Σ_v coef[v][u] c[v][y]
    let mut tmp = [0.0f32; 64];
    for y in 0..8 {
        for u in 0..8 {
            let mut acc = 0.0;
            for v in 0..8 {
                acc += coef[v * 8 + u] * b.c[v][y];
            }
            tmp[y * 8 + u] = acc;
        }
    }
    // Rows: out[y][x] = Σ_u tmp[y][u] c[u][x]
    let mut out = [0.0f32; 64];
    for y in 0..8 {
        for x in 0..8 {
            let mut acc = 0.0;
            for u in 0..8 {
                acc += tmp[y * 8 + u] * b.c[u][x];
            }
            out[y * 8 + x] = acc;
        }
    }
    out
}

/// Reference O(n⁴) forward DCT, used only by tests to validate the fast path.
pub fn fdct8x8_reference(block: &[f32; 64]) -> [f32; 64] {
    let mut out = [0.0f32; 64];
    for v in 0..8 {
        for u in 0..8 {
            let mut acc = 0.0f32;
            for y in 0..8 {
                for x in 0..8 {
                    acc += block[y * 8 + x]
                        * ((2 * x + 1) as f32 * u as f32 * std::f32::consts::PI / 16.0).cos()
                        * ((2 * y + 1) as f32 * v as f32 * std::f32::consts::PI / 16.0).cos();
                }
            }
            out[v * 8 + u] = 0.25 * Basis::alpha(u) * Basis::alpha(v) * acc * 4.0;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_block(seed: u64) -> [f32; 64] {
        let mut rng = Pcg32::seeded(seed);
        let mut b = [0.0f32; 64];
        for v in &mut b {
            *v = rng.range_f32(-128.0, 128.0);
        }
        b
    }

    #[test]
    fn fast_matches_reference() {
        for seed in 0..8 {
            let b = rand_block(seed);
            let fast = fdct8x8(&b);
            let slow = fdct8x8_reference(&b);
            for i in 0..64 {
                assert!((fast[i] - slow[i]).abs() < 1e-2, "i={i}: {} vs {}", fast[i], slow[i]);
            }
        }
    }

    #[test]
    fn forward_inverse_roundtrip() {
        for seed in 0..8 {
            let b = rand_block(100 + seed);
            let r = idct8x8(&fdct8x8(&b));
            for i in 0..64 {
                assert!((b[i] - r[i]).abs() < 1e-3, "i={i}");
            }
        }
    }

    #[test]
    fn dc_of_constant_block() {
        let b = [80.0f32; 64];
        let c = fdct8x8(&b);
        // DC = 8 * value for orthonormal scaling.
        assert!((c[0] - 8.0 * 80.0).abs() < 1e-2);
        for (i, &v) in c.iter().enumerate().skip(1) {
            assert!(v.abs() < 1e-3, "AC {i} = {v}");
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let b = rand_block(42);
        let c = fdct8x8(&b);
        let eb: f32 = b.iter().map(|v| v * v).sum();
        let ec: f32 = c.iter().map(|v| v * v).sum();
        assert!((eb - ec).abs() / eb < 1e-4, "{eb} vs {ec}");
    }
}

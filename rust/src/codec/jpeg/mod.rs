//! From-scratch baseline JPEG codec (the paper's compression baseline and
//! the format edge devices upload to the fog node).
//!
//! Pipeline: RGB→YCbCr → 4:2:0 subsampling → 8×8 DCT → quality-scaled
//! quantization → zigzag → DPCM/run-length → optimized canonical Huffman.

pub mod bitio;
pub mod coder;
pub mod color;
pub mod dct;
pub mod huffman;
pub mod quant;
pub mod zigzag;

pub use coder::{decode, encode};

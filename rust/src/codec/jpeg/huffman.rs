//! Canonical Huffman coding for the entropy stage of the baseline JPEG
//! codec. Per-image optimized tables (like `jpegtran -optimize`): the
//! encoder counts symbol frequencies, builds length-limited canonical
//! codes (≤ 16 bits, JPEG's limit), stores the `(counts-per-length,
//! symbols)` spec in the header, and the decoder reconstructs the same
//! codes via the standard MINCODE/MAXCODE/VALPTR procedure.

pub const MAX_CODE_LEN: usize = 16;

/// Canonical Huffman code table over byte symbols.
#[derive(Debug, Clone)]
pub struct HuffTable {
    /// `counts[l]` = number of codes with length `l+1` (l in 0..16).
    pub counts: [u8; MAX_CODE_LEN],
    /// Symbols in canonical order (shortest code first, then by symbol).
    pub symbols: Vec<u8>,
    /// Encoder lookup: symbol -> (code, length). len==0 means absent.
    enc: Vec<(u16, u8)>,
}

impl HuffTable {
    /// Build an optimal (length-limited) table from symbol frequencies.
    /// Symbols with zero frequency get no code. At least one symbol must
    /// have nonzero frequency.
    pub fn from_frequencies(freq: &[u64]) -> HuffTable {
        assert!(freq.len() <= 256);
        let mut lengths = huffman_code_lengths(freq);
        limit_lengths(&mut lengths, freq);
        Self::from_lengths(&lengths)
    }

    /// Build from per-symbol code lengths (0 = absent).
    pub fn from_lengths(lengths: &[u8]) -> HuffTable {
        let mut counts = [0u8; MAX_CODE_LEN];
        // Canonical order: by (length, symbol).
        let mut order: Vec<u8> = (0..lengths.len() as u16)
            .filter(|&s| lengths[s as usize] > 0)
            .map(|s| s as u8)
            .collect();
        order.sort_by_key(|&s| (lengths[s as usize], s));
        for &s in &order {
            counts[lengths[s as usize] as usize - 1] += 1;
        }
        let mut table =
            HuffTable { counts, symbols: order, enc: vec![(0, 0); lengths.len().max(256)] };
        table.rebuild_encoder();
        table
    }

    /// Reconstruct from the serialized `(counts, symbols)` spec.
    pub fn from_spec(counts: [u8; MAX_CODE_LEN], symbols: Vec<u8>) -> HuffTable {
        let total: usize = counts.iter().map(|&c| c as usize).sum();
        assert_eq!(total, symbols.len(), "huffman spec mismatch");
        let mut table = HuffTable { counts, symbols, enc: vec![(0, 0); 256] };
        table.rebuild_encoder();
        table
    }

    fn rebuild_encoder(&mut self) {
        for e in &mut self.enc {
            *e = (0, 0);
        }
        let mut code = 0u32; // u32: the trailing shift may exceed 16 bits
        let mut k = 0usize;
        for len in 1..=MAX_CODE_LEN {
            for _ in 0..self.counts[len - 1] {
                let sym = self.symbols[k];
                self.enc[sym as usize] = (code as u16, len as u8);
                code += 1;
                k += 1;
            }
            code <<= 1;
        }
    }

    /// `(code, length)` for a symbol; panics if the symbol has no code.
    pub fn encode(&self, sym: u8) -> (u16, u8) {
        let (c, l) = self.enc[sym as usize];
        assert!(l > 0, "symbol {sym} has no code");
        (c, l)
    }

    pub fn has(&self, sym: u8) -> bool {
        self.enc[sym as usize].1 > 0
    }

    /// Build the decoder acceleration arrays (JPEG F.2.2.3 style).
    pub fn decoder(&self) -> HuffDecoder {
        let mut mincode = [0i32; MAX_CODE_LEN + 1];
        let mut maxcode = [-1i32; MAX_CODE_LEN + 1];
        let mut valptr = [0usize; MAX_CODE_LEN + 1];
        let mut code = 0i32;
        let mut k = 0usize;
        for len in 1..=MAX_CODE_LEN {
            let n = self.counts[len - 1] as usize;
            if n > 0 {
                valptr[len] = k;
                mincode[len] = code;
                code += n as i32;
                maxcode[len] = code - 1;
                k += n;
            } else {
                maxcode[len] = -1;
            }
            code <<= 1;
        }
        HuffDecoder { mincode, maxcode, valptr, symbols: self.symbols.clone() }
    }
}

/// Decoder state built from a [`HuffTable`].
#[derive(Debug, Clone)]
pub struct HuffDecoder {
    mincode: [i32; MAX_CODE_LEN + 1],
    maxcode: [i32; MAX_CODE_LEN + 1],
    valptr: [usize; MAX_CODE_LEN + 1],
    symbols: Vec<u8>,
}

impl HuffDecoder {
    /// Decode one symbol from the bit reader.
    pub fn decode(&self, r: &mut super::bitio::BitReader<'_>) -> Option<u8> {
        let mut code = 0i32;
        for len in 1..=MAX_CODE_LEN {
            code = (code << 1) | r.bit()? as i32;
            if self.maxcode[len] >= 0 && code <= self.maxcode[len] && code >= self.mincode[len] {
                let idx = self.valptr[len] + (code - self.mincode[len]) as usize;
                return self.symbols.get(idx).copied();
            }
        }
        None
    }
}

/// Plain Huffman code lengths (unlimited) via pairwise merging.
fn huffman_code_lengths(freq: &[u64]) -> Vec<u8> {
    #[derive(Clone)]
    struct Node {
        weight: u64,
        // leaf symbol or internal children indices
        sym: Option<usize>,
        kids: Option<(usize, usize)>,
    }
    let mut nodes: Vec<Node> = Vec::new();
    let mut active: Vec<usize> = Vec::new();
    for (s, &f) in freq.iter().enumerate() {
        if f > 0 {
            nodes.push(Node { weight: f, sym: Some(s), kids: None });
            active.push(nodes.len() - 1);
        }
    }
    let mut lengths = vec![0u8; freq.len()];
    match active.len() {
        0 => return lengths,
        1 => {
            lengths[nodes[active[0]].sym.unwrap()] = 1;
            return lengths;
        }
        _ => {}
    }
    while active.len() > 1 {
        // Pull the two smallest (256 symbols max: linear scan is fine).
        active.sort_by_key(|&i| std::cmp::Reverse(nodes[i].weight));
        let a = active.pop().unwrap();
        let b = active.pop().unwrap();
        nodes.push(Node {
            weight: nodes[a].weight + nodes[b].weight,
            sym: None,
            kids: Some((a, b)),
        });
        active.push(nodes.len() - 1);
    }
    // DFS to assign depths.
    let root = active[0];
    let mut stack = vec![(root, 0u8)];
    while let Some((i, d)) = stack.pop() {
        if let Some(s) = nodes[i].sym {
            lengths[s] = d.max(1);
        } else if let Some((a, b)) = nodes[i].kids {
            stack.push((a, d + 1));
            stack.push((b, d + 1));
        }
    }
    lengths
}

/// Enforce the 16-bit length limit with JPEG Annex K.3 "Adjust_BITS":
/// operate on the counts-per-length histogram (which preserves the Kraft
/// sum exactly), then reassign lengths to symbols in the original
/// shortest-first order.
fn limit_lengths(lengths: &mut [u8], freq: &[u64]) {
    let max_len = lengths.iter().copied().max().unwrap_or(0) as usize;
    if max_len <= MAX_CODE_LEN {
        return;
    }
    // counts[l] = number of codes of length l (1-indexed).
    let mut counts = vec![0usize; max_len + 1];
    for &l in lengths.iter() {
        if l > 0 {
            counts[l as usize] += 1;
        }
    }
    // Adjust_BITS: fold levels deeper than MAX_CODE_LEN upward.
    for i in (MAX_CODE_LEN + 1..=max_len).rev() {
        while counts[i] > 0 {
            // Find the deepest level j < i-1 with a code to push down.
            let mut j = i - 2;
            while counts[j] == 0 {
                j -= 1;
            }
            counts[i] -= 2; // remove a leaf pair at depth i
            counts[i - 1] += 1; // their parent becomes a leaf
            counts[j + 1] += 2; // a leaf at depth j becomes internal w/ 2 leaves
            counts[j] -= 1;
        }
    }
    // Reassign: symbols ordered by (old length asc, freq desc) receive the
    // new lengths shortest-first, preserving optimality ordering.
    let mut order: Vec<usize> = (0..lengths.len()).filter(|&i| lengths[i] > 0).collect();
    order.sort_by(|&a, &b| {
        lengths[a]
            .cmp(&lengths[b])
            .then(freq[b].cmp(&freq[a]))
            .then(a.cmp(&b))
    });
    let mut k = 0usize;
    for (len, &cnt) in counts.iter().enumerate().take(MAX_CODE_LEN + 1).skip(1) {
        for _ in 0..cnt {
            lengths[order[k]] = len as u8;
            k += 1;
        }
    }
    debug_assert_eq!(k, order.len());
    debug_assert!(kraft_ok(lengths), "kraft violated after limiting");
}

/// Check the Kraft inequality Σ 2^-l ≤ 1 (decodability).
fn kraft_ok(lengths: &[u8]) -> bool {
    let mut sum = 0u64; // in units of 2^-MAX_CODE_LEN
    for &l in lengths {
        if l > 0 {
            sum += 1u64 << (MAX_CODE_LEN - l as usize);
        }
    }
    sum <= 1u64 << MAX_CODE_LEN
}

#[cfg(test)]
mod tests {
    use super::super::bitio::{BitReader, BitWriter};
    use super::*;
    use crate::util::rng::Pcg32;

    fn roundtrip_symbols(freq: &[u64], msg: &[u8]) {
        let table = HuffTable::from_frequencies(freq);
        let mut w = BitWriter::new();
        for &s in msg {
            let (c, l) = table.encode(s);
            w.write(c as u32, l);
        }
        let bytes = w.finish();
        let dec = table.decoder();
        let mut r = BitReader::new(&bytes);
        for &s in msg {
            assert_eq!(dec.decode(&mut r), Some(s));
        }
    }

    #[test]
    fn two_symbol_roundtrip() {
        let mut freq = vec![0u64; 256];
        freq[7] = 10;
        freq[42] = 3;
        roundtrip_symbols(&freq, &[7, 42, 7, 7, 42, 7]);
    }

    #[test]
    fn single_symbol_gets_one_bit() {
        let mut freq = vec![0u64; 256];
        freq[5] = 100;
        let t = HuffTable::from_frequencies(&freq);
        assert_eq!(t.encode(5).1, 1);
        roundtrip_symbols(&freq, &[5, 5, 5]);
    }

    #[test]
    fn skewed_distribution_roundtrip() {
        let mut rng = Pcg32::seeded(31);
        let mut freq = vec![0u64; 256];
        for s in 0..64u64 {
            freq[s as usize] = 1 + (1 << (s % 13));
        }
        let msg: Vec<u8> = (0..5_000).map(|_| rng.below(64) as u8).collect();
        roundtrip_symbols(&freq, &msg);
    }

    #[test]
    fn frequent_symbols_get_shorter_codes() {
        let mut freq = vec![0u64; 256];
        freq[0] = 1_000_000;
        freq[1] = 10;
        freq[2] = 10;
        freq[3] = 10;
        let t = HuffTable::from_frequencies(&freq);
        assert!(t.encode(0).1 <= t.encode(1).1);
    }

    #[test]
    fn lengths_capped_at_16() {
        // Fibonacci-ish frequencies force long codes without a limit.
        let mut freq = vec![0u64; 64];
        let mut a = 1u64;
        let mut b = 1u64;
        for f in freq.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let t = HuffTable::from_frequencies(&freq);
        for s in 0..64u8 {
            assert!(t.encode(s).1 as usize <= MAX_CODE_LEN);
        }
        // And it still decodes.
        let msg: Vec<u8> = (0..64).collect();
        roundtrip_symbols(&freq, &msg);
    }

    #[test]
    fn spec_roundtrip() {
        let mut freq = vec![0u64; 256];
        for s in 0..32 {
            freq[s] = (s as u64 + 1) * 7;
        }
        let t = HuffTable::from_frequencies(&freq);
        let t2 = HuffTable::from_spec(t.counts, t.symbols.clone());
        for s in 0..32u8 {
            assert_eq!(t.encode(s), t2.encode(s));
        }
    }

    #[test]
    fn property_random_frequencies_decode() {
        crate::util::propcheck::check("huffman-roundtrip", |rng| {
            let nsyms = 2 + rng.below_usize(100);
            let mut freq = vec![0u64; 256];
            for f in freq.iter_mut().take(nsyms) {
                *f = 1 + rng.below(1000) as u64;
            }
            let msg: Vec<u8> = (0..200).map(|_| rng.below(nsyms as u32) as u8).collect();
            let table = HuffTable::from_frequencies(&freq);
            let mut w = BitWriter::new();
            for &s in &msg {
                let (c, l) = table.encode(s);
                w.write(c as u32, l);
            }
            let bytes = w.finish();
            let dec = table.decoder();
            let mut r = BitReader::new(&bytes);
            for &s in &msg {
                assert_eq!(dec.decode(&mut r), Some(s));
            }
        });
    }
}

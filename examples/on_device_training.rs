//! END-TO-END driver (DESIGN.md "End-to-end validation"): the complete
//! fog on-device-learning pipeline on a real (synthetic) workload —
//! all three layers composing:
//!
//!   L3 rust coordinator → AOT HLO artifacts (L2 jax models, L1 Pallas
//!   decode kernels) via PJRT → simulated 2 MB/s wireless network.
//!
//! For each compression method: pretrain TinyDet on half the sequences,
//! upload the new sequences to the fog, INR-encode, broadcast, then
//! fine-tune on-device with grouped parallel decoding, logging the loss
//! curve and reporting accuracy, byte counts and the latency breakdown.
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```text
//! cargo run --release --example on_device_training            # default scale
//! FRAMES=48 EPOCHS=3 cargo run --release --example on_device_training
//! ```

use anyhow::Result;

use residual_inr::config::ArchConfig;
use residual_inr::coordinator::{run_sim, Method, SimConfig};
use residual_inr::data::Profile;
use residual_inr::util::fmt_bytes;

fn main() -> Result<()> {
    let frames: usize = std::env::var("FRAMES").ok().and_then(|v| v.parse().ok()).unwrap_or(32);
    let epochs: usize = std::env::var("EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let receivers: usize =
        std::env::var("RECEIVERS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let cfg = ArchConfig::load_default()?;

    let methods = [
        Method::Jpeg { quality: 95 },
        Method::RapidSingle,
        Method::ResRapid { direct: false },
        Method::Nerv,
        Method::ResNerv,
    ];

    println!("=== Residual-INR end-to-end on-device learning ===");
    println!(
        "profile uav123-like | {frames} fine-tune frames | {epochs} epochs | {receivers} receivers | 2 MB/s wireless\n"
    );
    let mut rows = Vec::new();
    for method in methods {
        let mut sim = SimConfig::small(method);
        sim.profile = Profile::Uav123;
        sim.n_sequences = 4;
        sim.epochs = epochs;
        sim.n_receivers = receivers;
        sim.pretrain_steps = 300;
        sim.enc = residual_inr::coordinator::EncoderConfig::default();
        sim.max_train_frames = Some(frames);
        sim.seed = 1234;
        eprintln!("--- {} (fog encoding runs now; off the edge critical path) ---", method.name());
        let r = run_sim(&cfg, &sim)?;
        eprintln!(
            "    encode {:.1}s | loss {:.4} -> {:.4} | mAP {:.3} -> {:.3}",
            r.fog_encode_seconds,
            r.loss_curve.first().copied().unwrap_or(f32::NAN),
            r.loss_curve.last().copied().unwrap_or(f32::NAN),
            r.map_before,
            r.map_after
        );
        // Log the loss curve for the e2e record (EXPERIMENTS.md).
        let curve: Vec<String> = r
            .loss_curve
            .iter()
            .step_by(r.loss_curve.len().div_ceil(12).max(1))
            .map(|l| format!("{l:.4}"))
            .collect();
        eprintln!("    loss curve: {}", curve.join(" "));
        rows.push(r);
    }

    println!(
        "\n{:<24} {:>10} {:>11} {:>8} {:>8} {:>8} {:>9} {:>8} {:>8}",
        "method", "net bytes", "frame payl", "tx s", "dec s", "train s", "e2e s", "mAP", "IoU"
    );
    println!("{}", "-".repeat(104));
    let jpeg_total = rows[0].total_bytes as f64;
    let jpeg_e2e = rows[0].edge_total_seconds();
    for r in &rows {
        println!(
            "{:<24} {:>10} {:>11} {:>8.2} {:>8.2} {:>8.2} {:>9.2} {:>8.3} {:>8.3}",
            r.method,
            fmt_bytes(r.total_bytes),
            fmt_bytes(r.avg_frame_bytes as u64),
            r.transmission_seconds,
            r.decode_seconds,
            r.train_seconds,
            r.edge_total_seconds(),
            r.map_after,
            r.mean_iou_after,
        );
    }
    println!("{}", "-".repeat(104));
    let res = &rows[2];
    println!(
        "Res-Rapid-INR vs JPEG: {:.2}x less data, {:.2}x end-to-end speedup (paper: up to 5.16x / 2.9x)",
        jpeg_total / res.total_bytes as f64,
        jpeg_e2e / res.edge_total_seconds(),
    );
    Ok(())
}

//! Compression quality/size sweep (the Fig 9 scenario as a runnable
//! example): encode the same frames under every technique — JPEG quality
//! ladder, Rapid-INR baseline, Res-Rapid-INR with residual vs direct
//! object encoding, 8- vs 16-bit background quantization — and report
//! (avg bytes/frame, object PSNR, background PSNR).
//!
//! ```text
//! cargo run --release --example compression_sweep
//! ```

use anyhow::Result;

use residual_inr::codec::jpeg;
use residual_inr::config::ArchConfig;
use residual_inr::coordinator::{EncoderConfig, FogEncoder};
use residual_inr::data::{generate_sequence, Profile};
use residual_inr::inr::{dequantize, quantize, Bits};
use residual_inr::metrics::{psnr_background, psnr_region};
use residual_inr::pipeline::decoder;
use residual_inr::runtime::Session;
use residual_inr::util::fmt_bytes;

fn main() -> Result<()> {
    let n_frames: usize =
        std::env::var("FRAMES").ok().and_then(|v| v.parse().ok()).unwrap_or(6);
    let cfg = ArchConfig::load_default()?;
    let session = Session::open_default()?;
    let profile = cfg.rapid(Profile::Uav123);
    let enc = FogEncoder::new(&session, &cfg, EncoderConfig::default());
    let seq = generate_sequence(Profile::Uav123, 77, 0);

    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new(); // name, bytes, obj, bg

    // JPEG quality ladder.
    for q in [20u8, 40, 60, 80, 95] {
        let (mut bytes, mut obj, mut bg) = (0.0, 0.0, 0.0);
        for i in 0..n_frames {
            let img = &seq.frames[i];
            let b = jpeg::encode(img, q);
            let dec = jpeg::decode(&b)?;
            bytes += b.len() as f64;
            obj += psnr_region(img, &dec, &seq.boxes[i]);
            bg += psnr_background(img, &dec, &seq.boxes[i]);
        }
        let n = n_frames as f64;
        rows.push((format!("JPEG q{q}"), bytes / n, obj / n, bg / n));
    }

    // Rapid-INR baseline (16-bit).
    {
        let (mut bytes, mut obj, mut bg) = (0.0, 0.0, 0.0);
        for i in 0..n_frames {
            let img = &seq.frames[i];
            let (ws, _) = enc.encode_rapid(img, &profile.baseline, i as u64)?;
            let q = quantize(&ws, Bits::B16);
            let dec = decoder::decode_rapid(
                &session, &profile.baseline, &dequantize(&q), img.width, img.height)?;
            bytes += q.byte_size() as f64;
            obj += psnr_region(img, &dec, &seq.boxes[i]);
            bg += psnr_background(img, &dec, &seq.boxes[i]);
        }
        let n = n_frames as f64;
        rows.push(("Rapid-INR 16b".into(), bytes / n, obj / n, bg / n));
    }

    // Res-Rapid-INR: residual vs direct, bg 8b vs 16b.
    for (label, direct, bg_bits) in [
        ("Res-Rapid (residual, bg 8b)", false, Bits::B8),
        ("Res-Rapid (residual, bg 16b)", false, Bits::B16),
        ("Res-Rapid (direct, bg 8b)", true, Bits::B8),
    ] {
        let mut ec = EncoderConfig::default();
        ec.bg_bits = bg_bits;
        let enc2 = FogEncoder::new(&session, &cfg, ec);
        let (mut bytes, mut obj, mut bg) = (0.0, 0.0, 0.0);
        for i in 0..n_frames {
            let img = &seq.frames[i];
            let r = enc2.encode_res_rapid(img, &seq.boxes[i], profile, direct, i as u64)?;
            let bin = &profile.object_bins[r.bin_idx];
            let bg_img = decoder::decode_rapid(
                &session, &profile.background, &dequantize(&r.bg), img.width, img.height)?;
            let patch = decoder::decode_object_patch(
                &session, bin, &dequantize(&r.obj), r.padded.w, r.padded.h)?;
            let recon = if direct {
                let mut out = bg_img.clone();
                out.paste(&patch, r.padded.x, r.padded.y);
                out.clamp01();
                out
            } else {
                decoder::compose_residual(&bg_img, &patch, &r.padded)
            };
            bytes += (r.bg.byte_size() + r.obj.byte_size()) as f64;
            obj += psnr_region(img, &recon, &seq.boxes[i]);
            bg += psnr_background(img, &recon, &seq.boxes[i]);
        }
        let n = n_frames as f64;
        rows.push((label.to_string(), bytes / n, obj / n, bg / n));
    }

    println!(
        "\n{:<30} {:>12} {:>11} {:>11}",
        "technique", "bytes/frame", "PSNR(obj)", "PSNR(bg)"
    );
    println!("{}", "-".repeat(68));
    for (name, bytes, obj, bg) in &rows {
        println!(
            "{:<30} {:>12} {:>11.2} {:>11.2}",
            name,
            fmt_bytes(*bytes as u64),
            obj,
            bg
        );
    }
    println!(
        "\nExpected shape (paper Fig 9): Res-Rapid at a fraction of JPEG's bytes \
         with object PSNR near the high-quality JPEG points, residual > direct \
         at equal size, and 8-bit background costing little object quality."
    );
    Ok(())
}

//! Quickstart: compress one synthetic UAV frame with Residual-INR,
//! transmit nothing — just show the core encode → quantize → decode →
//! compose loop and the size/quality numbers it produces.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use residual_inr::codec::jpeg;
use residual_inr::config::ArchConfig;
use residual_inr::coordinator::{EncoderConfig, FogEncoder};
use residual_inr::data::{generate_sequence, Profile};
use residual_inr::inr::dequantize;
use residual_inr::metrics::{psnr, psnr_background, psnr_region};
use residual_inr::pipeline::decoder;
use residual_inr::runtime::Session;
use residual_inr::util::fmt_bytes;

fn main() -> Result<()> {
    // 1. A synthetic UAV video frame with one small annotated object
    //    (the DAC-SDC-like dataset profile, DESIGN.md substitution table).
    let seq = generate_sequence(Profile::DacSdc, 42, 0);
    let img = &seq.frames[0];
    let bbox = &seq.boxes[0];
    println!(
        "frame {}x{}, object {}x{} at ({}, {}) — {:.1}% of the frame",
        img.width, img.height, bbox.w, bbox.h, bbox.x, bbox.y,
        100.0 * bbox.area_fraction(img.width, img.height)
    );

    // 2. The fog node: encode as background INR + residual object INR
    //    (paper §3.1). Encoding an INR = training it, via the AOT
    //    train-step artifacts on the PJRT CPU client.
    let session = Session::open_default()?;
    let cfg = ArchConfig::load_default()?;
    let profile = cfg.rapid(Profile::DacSdc);
    let enc = FogEncoder::new(&session, &cfg, EncoderConfig::default());
    println!("\nencoding (background INR {} params + object INR, residual targets)...",
             profile.background.param_count());
    let r = enc.encode_res_rapid(img, bbox, profile, false, 1)?;
    let bin = &profile.object_bins[r.bin_idx];
    println!(
        "  {} Adam steps in {:.1}s, object bin {} ({}x{} MLP)",
        r.stats.steps, r.stats.seconds, r.bin_idx, bin.arch.layers, bin.arch.hidden
    );

    // 3. The edge device: dequantize, decode background, overlay residual.
    let bg_img = decoder::decode_rapid(
        &session,
        &profile.background,
        &dequantize(&r.bg),
        img.width,
        img.height,
    )?;
    let patch = decoder::decode_object_patch(
        &session,
        bin,
        &dequantize(&r.obj),
        r.padded.w,
        r.padded.h,
    )?;
    let recon = decoder::compose_residual(&bg_img, &patch, &r.padded);

    // 4. Compare against JPEG at a few qualities (the paper's Fig 9 axes).
    let inr_bytes = r.bg.byte_size() + r.obj.byte_size();
    println!(
        "\n{:<26} {:>10} {:>12} {:>12} {:>12}",
        "method", "bytes", "PSNR(obj)", "PSNR(bg)", "PSNR(full)"
    );
    println!("{}", "-".repeat(76));
    println!(
        "{:<26} {:>10} {:>12.2} {:>12.2} {:>12.2}",
        "Res-Rapid-INR (8b bg/16b obj)",
        fmt_bytes(inr_bytes as u64),
        psnr_region(img, &recon, bbox),
        psnr_background(img, &recon, bbox),
        psnr(img, &recon),
    );
    println!(
        "{:<26} {:>10} {:>12.2} {:>12.2} {:>12.2}",
        "bg INR alone",
        fmt_bytes(r.bg.byte_size() as u64),
        psnr_region(img, &bg_img, bbox),
        psnr_background(img, &bg_img, bbox),
        psnr(img, &bg_img),
    );
    for q in [30u8, 60, 85] {
        let bytes = jpeg::encode(img, q);
        let dec = jpeg::decode(&bytes)?;
        println!(
            "{:<26} {:>10} {:>12.2} {:>12.2} {:>12.2}",
            format!("JPEG q{q}"),
            fmt_bytes(bytes.len() as u64),
            psnr_region(img, &dec, bbox),
            psnr_background(img, &dec, bbox),
            psnr(img, &dec),
        );
    }
    println!("\nResidual-INR keeps the *object* sharp at a fraction of the bytes; \
              the background is allowed to degrade (paper §3.1).");
    Ok(())
}

//! Multi-fog scale-out study on the discrete-event fleet engine.
//!
//! Takes the same Res-Rapid-INR workload through the three fleet
//! topologies — one big single-fog cell, four sharded fog cells over a
//! mesh backhaul, and a cloud→fog→edge hierarchy — and compares wireless
//! bytes, backhaul bytes, weight-cache dedup and makespan. The paper's
//! single-fog testbed (10 devices) is the calibration point; the
//! interesting regime is hundreds of receivers, where per-fog encode
//! worker pools and the content-addressed weight cache keep both the
//! timeline and the backhaul flat. The final section pushes past what
//! the per-receiver oracle can simulate: `--cell-mode aggregate`
//! collapses each (blob, cell) round into one closed-form macro
//! transaction, and the example prints the exact-vs-aggregate deltas
//! that justify trusting it at 10^5–10^6 edges.
//!
//! ```text
//! cargo run --release --example fleet_scaleout
//! EDGES=400 FOGS=8 cargo run --release --example fleet_scaleout
//! ```

use anyhow::Result;

use residual_inr::config::ArchConfig;
use residual_inr::coordinator::{EncoderConfig, Method};
use residual_inr::costmodel;
use residual_inr::data::Profile;
use residual_inr::fleet::{self, CellSimMode, FleetConfig, RebroadcastPolicy};
use residual_inr::util::fmt_bytes;

fn main() -> Result<()> {
    let cfg = ArchConfig::load_default()?;
    let edges: usize = std::env::var("EDGES").ok().and_then(|v| v.parse().ok()).unwrap_or(200);
    let fogs: usize = std::env::var("FOGS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let method = Method::ResRapid { direct: false };
    // Calibrated against live PJRT timing when artifacts exist.
    let costs = costmodel::auto(&cfg, Profile::DacSdc, method, &EncoderConfig::fast());
    println!("cost model: {}", costs.source.name());

    // 1. The paper's 10-device single-fog testbed as the anchor.
    let paper = fleet::run(&cfg, &FleetConfig::paper_10(method, costs))?;
    println!("--- paper-10 anchor ---");
    paper.print();

    // 2. One fog cell serving the whole fleet: every broadcast contends
    //    on a single shared medium.
    let mut single = FleetConfig::paper_10(method, costs);
    single.scenario = "single-big-cell".into();
    single.n_edges = edges;
    println!("\n--- single fog, {edges} edges ---");
    let r_single = fleet::run(&cfg, &single)?;
    r_single.print();

    // 3. Sharded: per-fog cells + mesh backhaul + weight cache.
    let mut sharded = FleetConfig::from_scenario("sharded", method, costs)?;
    sharded.n_fogs = fogs;
    sharded.n_edges = edges;
    println!("\n--- sharded, {fogs} fogs × {} edges ---", edges / fogs);
    let r_sharded = fleet::run(&cfg, &sharded)?;
    r_sharded.print();

    // 4. Hierarchical cloud relay.
    let mut hier = FleetConfig::from_scenario("hierarchical", method, costs)?;
    hier.n_fogs = fogs;
    hier.n_edges = edges;
    println!("\n--- hierarchical (cloud→fog→edge), {fogs} fogs ---");
    let r_hier = fleet::run(&cfg, &hier)?;
    r_hier.print();

    // 5. The same sharded fleet under each re-broadcast policy: unicast
    //    is the parity baseline; the others share cell airtime and
    //    dedup or tree-push the backhaul. The shard streams are
    //    policy-independent, so model them once and replay.
    println!("\n--- re-broadcast policies on the sharded fleet ---");
    let mut base = FleetConfig::from_scenario("sharded", method, costs)?;
    base.n_fogs = fogs;
    base.n_edges = edges;
    let shards = fleet::model_fleet_shards(&cfg, &base);
    let mut unicast_redis = 0u64;
    for policy in RebroadcastPolicy::ALL {
        let mut fc = base.clone();
        fc.policy = policy;
        let r = fleet::simulate(&fc, shards.clone());
        let redis = r.redistribution_bytes();
        if policy == RebroadcastPolicy::Unicast {
            unicast_redis = redis;
        }
        println!(
            "{:15}: {} broadcast+backhaul ({:.2}x vs unicast), airtime saved {:.2} s, \
             makespan {:.2} s",
            policy.name(),
            fmt_bytes(redis),
            unicast_redis as f64 / redis.max(1) as f64,
            r.airtime_saved_seconds,
            r.makespan_seconds
        );
    }

    // 6. The same fleet over lossy cells: every policy pays its own
    //    repair discipline's bill (ARQ retransmissions, NACK rounds,
    //    pull re-requests). Delivered bytes do not move; the wire
    //    overhead and the net airtime metric do — which is exactly what
    //    `--policy auto` decides by.
    println!("\n--- lossy cells (5% reception loss) ---");
    for policy in RebroadcastPolicy::ALL {
        let mut fc = base.clone();
        fc.policy = policy;
        fc.loss_cell = 0.05;
        let r = fleet::simulate(&fc, shards.clone());
        println!(
            "{:15}: {} delivered + {} repair + {} control (goodput {:.1}%), \
             airtime saved {:+.2} s",
            policy.name(),
            fmt_bytes(r.total_bytes),
            fmt_bytes(r.repair_bytes),
            fmt_bytes(r.control_bytes),
            100.0 * r.goodput_ratio(),
            r.airtime_saved_seconds
        );
    }

    // 7. Receiver churn: two devices join mid-run and catch up from the
    //    fog caches; the catch-up traffic is visible apart from the
    //    live broadcast totals.
    println!("\n--- receiver churn (2 joiners, cell-multicast) ---");
    let mut fc = base.clone();
    fc.policy = RebroadcastPolicy::CellMulticast;
    fc.joins = vec![
        residual_inr::fleet::JoinSpec { fog: 0, at: 5.0 },
        residual_inr::fleet::JoinSpec { fog: 1, at: 50.0 },
    ];
    let r = fleet::simulate(&fc, shards.clone());
    println!(
        "{} live broadcast + {} joiner catch-up, {} receivers (+{} joined), makespan {:.2} s",
        fmt_bytes(r.broadcast_bytes),
        fmt_bytes(r.catchup_bytes),
        r.n_receivers,
        r.joined_receivers,
        r.makespan_seconds
    );

    // 8. Aggregate cells: the scale mode. First validate it against the
    //    exact oracle at the current fleet size — delivered bytes must
    //    match to the byte at loss 0, makespan to float tolerance, while
    //    the event count collapses from per-receiver to per-blob. Then
    //    use it where the oracle is no longer practical.
    println!("\n--- aggregate cell mode: exact-vs-aggregate deltas ---");
    let run_mode = |mode: CellSimMode| {
        let mut fc = base.clone();
        fc.cell_sim = mode;
        fleet::simulate(&fc, shards.clone())
    };
    let exact = run_mode(CellSimMode::Exact);
    let agg = run_mode(CellSimMode::Aggregate);
    println!(
        "bytes   : exact {} vs aggregate {} (delta {} B — contract: 0 at loss 0)",
        fmt_bytes(exact.total_bytes),
        fmt_bytes(agg.total_bytes),
        (agg.total_bytes as i64 - exact.total_bytes as i64).abs()
    );
    println!(
        "makespan: exact {:.4} s vs aggregate {:.4} s (delta {:+.2e} s, float tolerance)",
        exact.makespan_seconds,
        agg.makespan_seconds,
        agg.makespan_seconds - exact.makespan_seconds
    );
    println!(
        "events  : exact {} vs aggregate {} ({:.0}x fewer — O(blobs), not O(receivers))",
        exact.events,
        agg.events,
        exact.events as f64 / agg.events.max(1) as f64
    );

    // With the contract demonstrated, scale the same fleet to 10^5 and
    // 10^6 edges — populations where the per-receiver oracle would burn
    // millions of events per shard round.
    for big in [100_000usize, 1_000_000] {
        let mut fc = base.clone();
        fc.n_edges = big;
        fc.cell_sim = CellSimMode::Aggregate;
        let t0 = std::time::Instant::now();
        let r = fleet::simulate(&fc, shards.clone());
        println!(
            "{:>9} edges: {} on air, makespan {:.2} s, {} events, simulated in {:.3} s",
            big,
            fmt_bytes(r.total_bytes),
            r.makespan_seconds,
            r.events,
            t0.elapsed().as_secs_f64()
        );
    }

    // 9. Streaming workloads: the same fleet run to steady state —
    //    frames keep arriving (Poisson, seeded) over a finite horizon,
    //    one device hands over between cells, one fog fails and its
    //    receivers re-elect onto the cheapest survivor, and every
    //    delivery is scored against a freshness deadline. Batch mode
    //    measures makespan; this measures staleness.
    println!("\n--- streaming: poisson:2 over 20 s, handover + fog failure ---");
    let mut fc = base.clone();
    fc.stream = Some(residual_inr::fleet::StreamConfig {
        arrivals: residual_inr::fleet::ArrivalSpec::Poisson { rate: 2.0 },
        horizon: 20.0,
        deadline: Some(0.5),
        shed: false,
    });
    fc.handovers = vec![residual_inr::fleet::HandoverSpec { from: 0, to: fogs - 1, at: 5.0 }];
    fc.fail = Some(residual_inr::fleet::FailSpec { fog: 1, at: 10.0 });
    let r = fleet::simulate(&fc, shards.clone());
    println!(
        "{} frames offered, {} deliveries, {} dropped (fog 1 fails at t=10)",
        r.frames_offered, r.stream_deliveries, r.frames_dropped
    );
    println!(
        "staleness p50 {:.3} s / p99 {:.3} s, deadline misses {:.1}%, goodput {}/s",
        r.staleness_p50_seconds,
        r.staleness_p99_seconds,
        100.0 * r.deadline_miss_rate(),
        fmt_bytes(r.stream_goodput_bytes_per_second() as u64)
    );
    for f in &r.fogs {
        println!(
            "fog {}: {} offered, {} dropped, +{} joined, -{} departed",
            f.fog, f.offered, f.dropped, f.joined, f.departed
        );
    }

    println!("\n--- summary ---");
    println!(
        "single cell : {} on air, makespan {:.2} s",
        fmt_bytes(r_single.total_bytes),
        r_single.makespan_seconds
    );
    println!(
        "sharded     : {} on air ({} backhaul), makespan {:.2} s, cache saved {}",
        fmt_bytes(r_sharded.total_bytes),
        fmt_bytes(r_sharded.backhaul_bytes),
        r_sharded.makespan_seconds,
        fmt_bytes(r_sharded.cache.bytes_saved)
    );
    println!(
        "hierarchical: {} on air ({} backhaul), makespan {:.2} s, cache saved {}",
        fmt_bytes(r_hier.total_bytes),
        fmt_bytes(r_hier.backhaul_bytes),
        r_hier.makespan_seconds,
        fmt_bytes(r_hier.cache.bytes_saved)
    );
    // Note the workloads differ: the single cell serves one shard, the
    // multi-fog fleets serve one shard *per fog* to every receiver, so
    // compare per-frame rates rather than raw makespans.
    let rate = |frames: usize, makespan: f64| frames as f64 / makespan.max(1e-9);
    println!(
        "delivery rate : single {:.1} frames/s vs sharded {:.1} frames/s ({} fog cells overlap)",
        rate(r_single.n_frames, r_single.makespan_seconds),
        rate(r_sharded.n_frames, r_sharded.makespan_seconds),
        fogs
    );
    Ok(())
}

//! Fog-network communication study (paper §4, Fig 8 + the headline
//! "5.16× less data across 10 devices").
//!
//! Uses the measured INR compression ratio α from an actual encode of a
//! synthetic dataset, then sweeps the analytical model: total bytes vs
//! number of devices (all-to-all) and vs receivers-per-device, comparing
//! serverless JPEG exchange against fog INR compression, and simulates
//! the transfers over the 2 MB/s wireless medium.
//!
//! ```text
//! cargo run --release --example fog_network
//! ```

use anyhow::Result;

use residual_inr::commmodel as cm;
use residual_inr::config::ArchConfig;
use residual_inr::coordinator::{EncoderConfig, FogNode, Method};
use residual_inr::data::{generate_dataset, Profile};
use residual_inr::net::{NetSim, NodeId};
use residual_inr::runtime::Session;
use residual_inr::util::fmt_bytes;

fn main() -> Result<()> {
    // 1. Measure α = INR size / JPEG size on real encodes (8 frames).
    let cfg = ArchConfig::load_default()?;
    let session = Session::open_default()?;
    let fog = FogNode::new(&session, &cfg, EncoderConfig::fast());
    let mut ds = generate_dataset(Profile::Uav123, 11, 1);
    ds.sequences[0].frames.truncate(8);
    ds.sequences[0].boxes.truncate(8);
    let jpeg = fog.compress(&ds, Method::Jpeg { quality: 95 })?;
    let res = fog.compress(&ds, Method::ResRapid { direct: false })?;
    let alpha = res.payload_bytes as f64 / jpeg.payload_bytes as f64;
    println!(
        "measured on {} frames: JPEG {} vs Res-Rapid-INR {}  →  α = {:.3}",
        jpeg.n_frames,
        fmt_bytes(jpeg.payload_bytes as u64),
        fmt_bytes(res.payload_bytes as u64),
        alpha
    );

    // 2. Fig 8(a): total transmission vs number of devices, all-to-all.
    let m = jpeg.avg_frame_bytes() * 100.0; // 100 frames per device
    println!("\nFig 8(a): all-to-all, {} per device", fmt_bytes(m as u64));
    println!("{:>4} {:>14} {:>14} {:>9}", "k", "serverless", "fog+INR", "gain");
    for k in [2usize, 4, 6, 8, 10, 12] {
        let s = cm::serverless_total(&cm::uniform_all_to_all(k, m, false));
        let f = cm::fog_total(&cm::uniform_all_to_all(k, m, true), alpha);
        println!(
            "{:>4} {:>14} {:>14} {:>8.2}x",
            k,
            fmt_bytes(s as u64),
            fmt_bytes(f as u64),
            s / f
        );
    }

    // 3. Fig 8(b): k = 11 devices, sweep receivers per device.
    println!("\nFig 8(b): k = 11 devices, receivers per device swept");
    println!("{:>4} {:>14} {:>14} {:>9}  fog wins?", "n", "serverless", "fog+INR", "gain");
    let thr = cm::min_receivers_for_fog(alpha);
    for n in 1..=10usize {
        let s = cm::serverless_total(&cm::uniform_fixed_receivers(11, n, m, false));
        let f = cm::fog_total(&cm::uniform_fixed_receivers(11, n, m, true), alpha);
        println!(
            "{:>4} {:>14} {:>14} {:>8.2}x  {}",
            n,
            fmt_bytes(s as u64),
            fmt_bytes(f as u64),
            s / f,
            if cm::fog_beneficial(n, alpha) { "yes" } else { "no " }
        );
    }
    println!(
        "crossover: fog wins from n_i >= {:?} (paper: n_i > 1/(1-α) = {:.2})",
        thr,
        1.0 / (1.0 - alpha)
    );

    // 4. Simulated wireless transfers at 2 MB/s for k = 10 (headline).
    let k = 10;
    let mut net = NetSim::paper_default();
    let nodes: Vec<NodeId> = (0..k).map(NodeId::Edge).collect();
    for &src in &nodes {
        let rx: Vec<NodeId> = nodes.iter().copied().filter(|&n| n != src).collect();
        net.broadcast(src, &rx, m as u64, "serverless");
    }
    let t_serverless = net.total_seconds();
    let b_serverless = net.total_bytes();
    net.reset();
    for &src in &nodes {
        net.send(src, NodeId::Fog, m as u64, "upload");
        let rx: Vec<NodeId> = nodes.iter().copied().filter(|&n| n != src).collect();
        net.broadcast(NodeId::Fog, &rx, (alpha * m) as u64, "inr");
    }
    let t_fog = net.total_seconds();
    let b_fog = net.total_bytes();
    println!("\nsimulated wireless @ 2 MB/s, k = {k}, all-to-all:");
    println!("  serverless : {}  ({:.1} s airtime)", fmt_bytes(b_serverless), t_serverless);
    println!("  fog + INR  : {}  ({:.1} s airtime)", fmt_bytes(b_fog), t_fog);
    println!(
        "  reduction  : {:.2}x  (paper reports 3.43–5.16x at k = 10)",
        b_serverless as f64 / b_fog as f64
    );
    Ok(())
}

"""L2 correctness: train steps actually learn, NeRV decodes, TinyDet
regresses boxes, Adam matches a hand-rolled reference, and the artifact
signatures in the manifest stay consistent with the model shapes."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import mlp_decode as kmlp
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture(scope="module")
def cfg():
    with open(os.path.join(ROOT, "configs", "arch.json")) as f:
        return json.load(f)


def init_state(shapes, seed=0):
    key = jax.random.PRNGKey(seed)
    params = model.siren_init(key, shapes)
    zeros = [jnp.zeros_like(p) for p in params]
    return params, zeros, [jnp.zeros_like(p) for p in params]


class TestRapidTrainStep:
    def test_loss_decreases_on_target_image(self):
        arch = {"layers": 4, "hidden": 16, "posenc": 6, "sigmoid_out": True}
        shapes = model.mlp_param_shapes(arch)
        params, m, v = init_state(shapes, 1)
        step_fn = jax.jit(model.make_rapid_train_step(arch))
        n = 32 * 32
        coords = ref.frame_grid(32, 32)
        # Smooth target: a cheap stand-in for a background frame.
        targets = jnp.stack([
            0.5 + 0.4 * jnp.sin(4 * coords[:, 0]),
            0.5 + 0.4 * jnp.cos(3 * coords[:, 1]),
            0.5 + 0.2 * jnp.sin(5 * (coords[:, 0] + coords[:, 1])),
        ], axis=-1)
        mask = jnp.ones((n,))
        losses = []
        nt = len(shapes)
        for step in range(60):
            out = step_fn(*params, *m, *v, jnp.float32(step + 1), coords, targets, mask)
            params = list(out[:nt])
            m = list(out[nt:2 * nt])
            v = list(out[2 * nt:3 * nt])
            losses.append(float(out[-1]))
        assert losses[-1] < losses[0] * 0.2, losses[::10]

    def test_mask_excludes_pixels(self):
        arch = {"layers": 3, "hidden": 8, "posenc": 4, "sigmoid_out": False}
        shapes = model.mlp_param_shapes(arch)
        params, m, v = init_state(shapes, 2)
        step_fn = jax.jit(model.make_rapid_train_step(arch))
        coords = ref.frame_grid(8, 8)
        targets = jnp.zeros((64, 3))
        # Poison the masked-out half with huge values: loss must ignore it.
        targets = targets.at[32:].set(1e6)
        mask = jnp.concatenate([jnp.ones(32), jnp.zeros(32)])
        out = step_fn(*params, *m, *v, jnp.float32(1), coords, targets, mask)
        assert float(out[-1]) < 1e3

    def test_train_then_pallas_decode_consistent(self):
        # What production does: train with the jnp path (fog), decode with
        # the Pallas kernel (edge). The two forwards must agree.
        arch = {"layers": 3, "hidden": 10, "posenc": 4, "sigmoid_out": False}
        shapes = model.mlp_param_shapes(arch)
        params, _, _ = init_state(shapes, 3)
        coords = ref.patch_grid(18)
        a = ref.mlp_decode(params, coords, 4, False)
        b = kmlp.fused_mlp_decode(params, coords, 4, False)
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


class TestAdam:
    def test_matches_manual_reference(self):
        params = [jnp.array([1.0, -2.0]), jnp.array([[0.5]])]
        grads = [jnp.array([0.1, -0.3]), jnp.array([[1.0]])]
        m = [jnp.zeros(2), jnp.zeros((1, 1))]
        v = [jnp.zeros(2), jnp.zeros((1, 1))]
        lr = 1e-2
        new_p, new_m, new_v = model.adam_update(params, grads, m, v, 1.0, lr)
        for p, g, np_, nm, nv in zip(params, grads, new_p, new_m, new_v):
            m1 = 0.1 * np.asarray(g)  # (1-b1)*g at step 1
            v1 = 0.001 * np.asarray(g) ** 2
            mhat = m1 / (1 - 0.9)
            vhat = v1 / (1 - 0.999)
            want = np.asarray(p) - lr * mhat / (np.sqrt(vhat) + model.ADAM_EPS)
            assert_allclose(np.asarray(np_), want, rtol=1e-5)
            assert_allclose(np.asarray(nm), m1, rtol=1e-6)
            assert_allclose(np.asarray(nv), v1, rtol=1e-6)


class TestNerv:
    ARCH = {"posenc": 6, "dim1": 64, "c0": 6, "channels": [12, 10, 8],
            "h0": 12, "w0": 16}

    def test_decode_shape_and_range(self):
        shapes = model.nerv_param_shapes(self.ARCH)
        params, _, _ = init_state(shapes, 4)
        t = jnp.array([0.0, 0.33, 0.66, 1.0])
        frames = ref.nerv_decode(params, t, self.ARCH)
        assert frames.shape == (4, 96, 128, 3)
        assert bool(jnp.all((frames >= 0) & (frames <= 1)))

    def test_pallas_stem_matches_ref(self):
        shapes = model.nerv_param_shapes(self.ARCH)
        params, _, _ = init_state(shapes, 5)
        t = jnp.array([0.1, 0.5, 0.9, 0.2])
        a = ref.nerv_decode(params, t, self.ARCH)
        b = model.nerv_decode_pallas(params, t, self.ARCH)
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    def test_train_reduces_loss(self):
        shapes = model.nerv_param_shapes(self.ARCH)
        params, m, v = init_state(shapes, 6)
        step_fn = jax.jit(model.make_nerv_train_step(self.ARCH))
        t = jnp.array([0.0, 0.33, 0.66, 1.0])
        ys, xs = jnp.meshgrid(jnp.linspace(0, 1, 96), jnp.linspace(0, 1, 128),
                              indexing="ij")
        base = jnp.stack([xs, ys, 0.5 * (xs + ys)], axis=-1)
        frames = jnp.stack([jnp.clip(base + 0.1 * i, 0, 1) for i in range(4)])
        nt = len(shapes)
        losses = []
        for step in range(30):
            out = step_fn(*params, *m, *v, jnp.float32(step + 1), t, frames)
            params = list(out[:nt])
            m = list(out[nt:2 * nt])
            v = list(out[2 * nt:3 * nt])
            losses.append(float(out[-1]))
        assert losses[-1] < losses[0] * 0.5, losses[::6]


class TestTinyDet:
    CFG = {"batch": 8, "base_channels": 8, "stages": 3, "head_hidden": 32}
    FRAME = {"width": 64, "height": 48}

    def _images_boxes(self, seed, b=8):
        rng = np.random.default_rng(seed)
        h, w = self.FRAME["height"], self.FRAME["width"]
        imgs = np.full((b, h, w, 3), 0.3, np.float32)
        boxes = np.zeros((b, 4), np.float32)
        for i in range(b):
            bw, bh = rng.integers(8, 16), rng.integers(6, 12)
            x = rng.integers(0, w - bw)
            y = rng.integers(0, h - bh)
            imgs[i, y:y + bh, x:x + bw] = [0.9, 0.1, 0.2]
            boxes[i] = [(x + bw / 2) / w, (y + bh / 2) / h, bw / w, bh / h]
        return jnp.asarray(imgs), jnp.asarray(boxes)

    def test_forward_shapes(self):
        shapes = model.detect_param_shapes(self.CFG, self.FRAME)
        params, _, _ = init_state(shapes, 7)
        imgs, _ = self._images_boxes(0)
        box, conf = model.tinydet_forward(params, imgs, self.CFG)
        assert box.shape == (8, 4) and conf.shape == (8,)
        assert bool(jnp.all((box >= 0) & (box <= 1)))

    def test_training_improves_iou(self):
        shapes = model.detect_param_shapes(self.CFG, self.FRAME)
        params, m, v = init_state(shapes, 8)
        step_fn = jax.jit(model.make_tinydet_train_step(self.CFG, self.FRAME))
        nt = len(shapes)
        imgs, boxes = self._images_boxes(1)
        first_loss = last_loss = None
        for step in range(150):
            out = step_fn(*params, *m, *v, jnp.float32(step + 1), imgs, boxes)
            params = list(out[:nt])
            m = list(out[nt:2 * nt])
            v = list(out[2 * nt:3 * nt])
            loss = float(out[-1])
            first_loss = first_loss if first_loss is not None else loss
            last_loss = loss
        assert last_loss < first_loss * 0.5, (first_loss, last_loss)
        pred, conf = model.tinydet_forward(params, imgs, self.CFG)
        iou = model.iou_cxcywh(pred, boxes)
        assert float(jnp.mean(iou)) > 0.25, float(jnp.mean(iou))

    def test_iou_cxcywh_known_values(self):
        a = jnp.array([[0.5, 0.5, 0.2, 0.2]])
        assert_allclose(np.asarray(model.iou_cxcywh(a, a)), [1.0], rtol=1e-6)
        b = jnp.array([[0.9, 0.9, 0.1, 0.1]])
        assert float(model.iou_cxcywh(a, b)[0]) == 0.0


class TestManifestConsistency:
    def test_manifest_matches_model_shapes(self, cfg):
        path = os.path.join(ROOT, "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            manifest = json.load(f)
        assert len(manifest) >= 40
        # Every rapid_decode artifact's weight args must match
        # mlp_param_shapes of its meta arch.
        for name, entry in manifest.items():
            if entry["kind"] != "rapid_decode":
                continue
            arch = entry["meta"]["arch"]
            shapes = model.mlp_param_shapes(arch)
            got = entry["args"][:len(shapes)]
            for (wn, ws), (gn, gs) in zip(shapes, got):
                assert wn == gn and list(ws) == list(gs), (name, wn, ws, gn, gs)
            n = entry["meta"]["n"]
            assert entry["args"][-1] == ["coords", [n, 2]]
            assert entry["outputs"] == [["rgb", [n, 3]]]

    def test_train_artifacts_have_state_triplets(self, cfg):
        path = os.path.join(ROOT, "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            manifest = json.load(f)
        for name, entry in manifest.items():
            if not entry["kind"].endswith("_train"):
                continue
            args = [a[0] for a in entry["args"]]
            outs = [o[0] for o in entry["outputs"]]
            n_params = sum(1 for a in args if not a.startswith(("m_", "v_"))
                           and a not in ("step",) and not a.startswith(
                               ("coords", "targets", "mask", "t", "frames",
                                "images", "boxes")))
            assert args.count("step") == 1
            assert sum(a.startswith("m_") for a in args) == n_params
            assert sum(a.startswith("v_") for a in args) == n_params
            assert outs[-1] == "loss"
            assert len(outs) == 3 * n_params + 1

"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

This is the core kernel correctness signal. Hypothesis sweeps shapes and
value ranges; `assert_allclose` against `ref` at tight tolerances (both
paths are f32; interpret-mode Pallas should match to reassociation-level
error).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import mlp_decode as kmlp
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_mlp(rng, layers, hidden, freqs):
    dims = [ref.posenc_dim(2, freqs)] + [hidden] * (layers - 1) + [3]
    params = []
    for i in range(layers):
        fan_in = dims[i]
        bound = (6.0 / fan_in) ** 0.5
        params.append(jnp.asarray(
            rng.uniform(-bound, bound, (dims[i], dims[i + 1])).astype(np.float32)))
        params.append(jnp.asarray(
            rng.uniform(-0.01, 0.01, (dims[i + 1],)).astype(np.float32)))
    return params


class TestFusedMlpDecode:
    @pytest.mark.parametrize("layers,hidden,freqs,sigmoid", [
        (2, 6, 4, False),
        (3, 10, 4, False),
        (6, 12, 6, True),
        (10, 28, 6, True),
    ])
    def test_matches_ref_table1_archs(self, layers, hidden, freqs, sigmoid):
        rng = np.random.default_rng(layers * 100 + hidden)
        params = make_mlp(rng, layers, hidden, freqs)
        coords = jnp.asarray(rng.uniform(0, 1, (777, 2)).astype(np.float32))
        want = ref.mlp_decode(params, coords, freqs, sigmoid)
        got = kmlp.fused_mlp_decode(params, coords, freqs, sigmoid)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 2000),
        layers=st.integers(2, 6),
        hidden=st.integers(4, 32),
        freqs=st.integers(1, 8),
        sigmoid=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, n, layers, hidden, freqs, sigmoid, seed):
        rng = np.random.default_rng(seed)
        params = make_mlp(rng, layers, hidden, freqs)
        coords = jnp.asarray(rng.uniform(0, 1, (n, 2)).astype(np.float32))
        want = ref.mlp_decode(params, coords, freqs, sigmoid)
        got = kmlp.fused_mlp_decode(params, coords, freqs, sigmoid)
        assert got.shape == (n, 3)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_block_size_invariance(self):
        rng = np.random.default_rng(7)
        params = make_mlp(rng, 4, 16, 6)
        coords = jnp.asarray(rng.uniform(0, 1, (1000, 2)).astype(np.float32))
        a = kmlp.fused_mlp_decode(params, coords, 6, True, block_n=64)
        b = kmlp.fused_mlp_decode(params, coords, 6, True, block_n=512)
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)

    def test_full_frame_grid(self):
        # The exact shape the edge decode path uses: 128x96 frame.
        rng = np.random.default_rng(3)
        params = make_mlp(rng, 6, 12, 6)
        coords = ref.frame_grid(128, 96)
        out = kmlp.fused_mlp_decode(params, coords, 6, True)
        assert out.shape == (128 * 96, 3)
        assert bool(jnp.all((out >= 0) & (out <= 1)))

    def test_output_finite_extreme_weights(self):
        rng = np.random.default_rng(11)
        params = [p * 100.0 for p in make_mlp(rng, 3, 8, 4)]
        coords = jnp.asarray(rng.uniform(0, 1, (64, 2)).astype(np.float32))
        out = kmlp.fused_mlp_decode(params, coords, 4, True)
        assert bool(jnp.all(jnp.isfinite(out)))


class TestMatmulBias:
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 300),
        k=st.integers(1, 64),
        n=st.integers(1, 128),
        act=st.sampled_from(["none", "sin", "relu", "sigmoid"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, m, k, n, act, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        want = ref.matmul_bias(x, w, b, act)
        got = kmlp.matmul_bias(x, w, b, act)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_nerv_stem_shape(self):
        # The actual NeRV stem: (4, 13) @ (13, 64) then (4, 64) @ (64, 1152).
        rng = np.random.default_rng(5)
        pe = jnp.asarray(rng.normal(size=(4, 13)).astype(np.float32))
        w1 = jnp.asarray(rng.normal(size=(13, 64)).astype(np.float32))
        b1 = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        h = kmlp.matmul_bias(pe, w1, b1, "sin")
        assert_allclose(np.asarray(h), np.asarray(ref.matmul_bias(pe, w1, b1, "sin")),
                        rtol=1e-5, atol=1e-5)


class TestPosenc:
    def test_dims(self):
        x = jnp.zeros((5, 2))
        assert ref.posenc(x, 6).shape == (5, ref.posenc_dim(2, 6))
        assert ref.posenc_dim(2, 6) == 26

    def test_grid_layout_row_major(self):
        g = ref.frame_grid(4, 3)
        assert g.shape == (12, 2)
        # index i = y*width + x; coords = [x_norm, y_norm]
        assert_allclose(np.asarray(g[0]), [0.5 / 4, 0.5 / 3], rtol=1e-6)
        assert_allclose(np.asarray(g[1]), [1.5 / 4, 0.5 / 3], rtol=1e-6)
        assert_allclose(np.asarray(g[4]), [0.5 / 4, 1.5 / 3], rtol=1e-6)

    def test_vmem_estimate_reasonable(self):
        shapes = [(26, 28), (28,), (28, 28), (28,), (28, 3), (3,)]
        v = kmlp.vmem_estimate_bytes(shapes, 512, 6)
        assert 0 < v < 16 * 2**20  # must fit VMEM comfortably

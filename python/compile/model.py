"""Layer-2 JAX models: everything the rust coordinator executes via PJRT.

Families (all lowered to HLO text by ``aot.py``; flat positional argument
lists define the artifact parameter order the rust runtime marshals):

* **Rapid-INR decode** — fused Pallas coordinate-MLP (`kernels.mlp_decode`),
  the edge-device decode hot path.
* **Rapid-INR train step** — one fused Adam step on (masked) MSE, run by
  the fog node's encoder loop. jnp fwd/bwd (autodiff through interpret-mode
  ``pallas_call`` is unsupported); numerics identical to the kernel path,
  which pytest asserts.
* **NeRV decode / train step** — video INR; decode uses the Pallas matmul
  kernel for the stem (NeRV's dominant matmul), convs lower to XLA fusions.
* **TinyDet fwd / train step** — the detection backbone stand-in for
  YOLOv8 (DESIGN.md): conv pyramid + box/confidence regression head;
  confidence is trained against the IoU of the predicted box (YOLO-style
  objectness), making it a meaningful mAP ranking signal.

Adam is fused into every train-step artifact: one PJRT call per step, no
per-tensor dispatch from rust (L2 perf target, DESIGN.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import mlp_decode as kmlp
from .kernels import ref

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
INR_LR = 1e-2  # lr sweep in EXPERIMENTS.md §Perf L2: +4dB over 2e-3 at equal steps
DET_LR = 1e-3


# --------------------------------------------------------------------------
# Shared pieces
# --------------------------------------------------------------------------

def mlp_param_shapes(arch: dict) -> list[tuple[str, tuple[int, ...]]]:
    """Mirror of rust `MlpArch::param_shapes` (same names, same order)."""
    layers, hidden = arch["layers"], arch["hidden"]
    in_dim = ref.posenc_dim(2, arch["posenc"])
    dims = [in_dim] + [hidden] * (layers - 1) + [3]
    shapes = []
    for l in range(layers):
        shapes.append((f"w{l}", (dims[l], dims[l + 1])))
        shapes.append((f"b{l}", (dims[l + 1],)))
    return shapes


def nerv_param_shapes(arch: dict) -> list[tuple[str, tuple[int, ...]]]:
    """Mirror of rust `NervArch::param_shapes`."""
    t_dim = 1 + 2 * arch["posenc"]
    dim2 = arch["c0"] * arch["h0"] * arch["w0"]
    shapes = [
        ("stem_w1", (t_dim, arch["dim1"])),
        ("stem_b1", (arch["dim1"],)),
        ("stem_w2", (arch["dim1"], dim2)),
        ("stem_b2", (dim2,)),
    ]
    cin = arch["c0"]
    for i, cout in enumerate(arch["channels"]):
        shapes.append((f"conv{i}_w", (3, 3, cin, 4 * cout)))
        shapes.append((f"conv{i}_b", (4 * cout,)))
        cin = cout
    shapes.append(("head_w", (3, 3, cin, 3)))
    shapes.append(("head_b", (3,)))
    return shapes


def detect_param_shapes(cfg: dict, frame: dict) -> list[tuple[str, tuple[int, ...]]]:
    """TinyDet parameter shapes: `stages` stride-2 3×3 convs doubling
    channels from `base_channels`, the final feature map flattened
    (preserving the spatial information a box regressor needs), then a
    2-layer MLP head to 5 outputs."""
    shapes = []
    cin = 3
    c = cfg["base_channels"]
    for i in range(cfg["stages"]):
        shapes.append((f"conv{i}_w", (3, 3, cin, c)))
        shapes.append((f"conv{i}_b", (c,)))
        cin = c
        c *= 2
    ds = 2 ** cfg["stages"]
    fh = -(-frame["height"] // ds)  # ceil div (SAME padding)
    fw = -(-frame["width"] // ds)
    shapes.append(("head_w1", (fh * fw * cin, cfg["head_hidden"])))
    shapes.append(("head_b1", (cfg["head_hidden"],)))
    shapes.append(("head_w2", (cfg["head_hidden"], 5)))
    shapes.append(("head_b2", (5,)))
    return shapes


def siren_init(key, shapes):
    """SIREN-style uniform init: W ~ U(±sqrt(6/fan_in)), b ~ U(±1/sqrt(fan_in)).

    The rust coordinator reproduces this distribution with its own RNG when
    it initializes fresh INRs (`coordinator::encoder`).
    """
    params = []
    for name, shape in shapes:
        key, sub = jax.random.split(key)
        if len(shape) >= 2:
            fan_in = int(jnp.prod(jnp.array(shape[:-1])))
            bound = (6.0 / fan_in) ** 0.5
        else:
            bound = 0.01
        params.append(jax.random.uniform(sub, shape, jnp.float32, -bound, bound))
    return params


def adam_update(params, grads, m, v, step, lr):
    """One fused Adam step over flat parameter lists."""
    b1t = 1.0 - ADAM_B1 ** step
    b2t = 1.0 - ADAM_B2 ** step
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        mhat = mi / b1t
        vhat = vi / b2t
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


# --------------------------------------------------------------------------
# Rapid-INR artifacts
# --------------------------------------------------------------------------

def make_rapid_decode(arch: dict):
    """Artifact fn: (w0, b0, ..., coords[N,2]) -> rgb[N,3] (Pallas path)."""
    freqs, sig = arch["posenc"], arch["sigmoid_out"]

    def fn(*args):
        params, coords = list(args[:-1]), args[-1]
        return (kmlp.fused_mlp_decode(params, coords, freqs, sig),)

    return fn


def make_rapid_train_step(arch: dict, lr: float = INR_LR):
    """Artifact fn: (params…, m…, v…, step, coords[N,2], targets[N,3],
    mask[N]) -> (params'…, m'…, v'…, loss). Masked MSE; one Adam step."""
    freqs, sig = arch["posenc"], arch["sigmoid_out"]
    n_tensors = len(mlp_param_shapes(arch))

    def loss_fn(params, coords, targets, mask):
        pred = ref.mlp_decode(params, coords, freqs, sig)
        se = jnp.sum((pred - targets) ** 2, axis=-1) * mask
        return jnp.sum(se) / (jnp.maximum(jnp.sum(mask), 1.0) * 3.0)

    def fn(*args):
        params = list(args[:n_tensors])
        m = list(args[n_tensors:2 * n_tensors])
        v = list(args[2 * n_tensors:3 * n_tensors])
        step, coords, targets, mask = args[3 * n_tensors:]
        loss, grads = jax.value_and_grad(loss_fn)(params, coords, targets, mask)
        new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr)
        return tuple(new_p + new_m + new_v + [loss])

    return fn


# --------------------------------------------------------------------------
# NeRV artifacts
# --------------------------------------------------------------------------

def nerv_decode_pallas(params, t, arch):
    """NeRV forward with the Pallas matmul kernel on the stem layers."""
    pe = ref.posenc(t[:, None], arch["posenc"])
    h = kmlp.matmul_bias(pe, params[0], params[1], "sin")
    h = kmlp.matmul_bias(h, params[2], params[3], "none")
    b = t.shape[0]
    x = h.reshape(b, arch["h0"], arch["w0"], arch["c0"])
    idx = 4
    for cout in arch["channels"]:
        w, bias = params[idx], params[idx + 1]
        idx += 2
        x = jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + bias
        x = ref.pixel_shuffle(x, 2)
        x = jnp.maximum(x, 0.0)
    w, bias = params[idx], params[idx + 1]
    x = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + bias
    return ref.jax_sigmoid(x)


def make_nerv_decode(arch: dict):
    """Artifact fn: (params…, t[B]) -> frames[B,H,W,3]."""

    def fn(*args):
        params, t = list(args[:-1]), args[-1]
        return (nerv_decode_pallas(params, t, arch),)

    return fn


def make_nerv_train_step(arch: dict, lr: float = INR_LR):
    """Artifact fn: (params…, m…, v…, step, t[B], frames[B,H,W,3])
    -> (params'…, m'…, v'…, loss)."""
    n_tensors = len(nerv_param_shapes(arch))

    def loss_fn(params, t, frames):
        pred = ref.nerv_decode(params, t, arch)
        return jnp.mean((pred - frames) ** 2)

    def fn(*args):
        params = list(args[:n_tensors])
        m = list(args[n_tensors:2 * n_tensors])
        v = list(args[2 * n_tensors:3 * n_tensors])
        step, t, frames = args[3 * n_tensors:]
        loss, grads = jax.value_and_grad(loss_fn)(params, t, frames)
        new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr)
        return tuple(new_p + new_m + new_v + [loss])

    return fn


# --------------------------------------------------------------------------
# TinyDet (detection backbone)
# --------------------------------------------------------------------------

def tinydet_forward(params, images, cfg: dict):
    """images (B,H,W,3) -> (box[B,4] in [0,1] cxcywh, conf[B] in [0,1])."""
    x = images
    idx = 0
    for _ in range(cfg["stages"]):
        w, b = params[idx], params[idx + 1]
        idx += 2
        x = jax.lax.conv_general_dilated(
            x, w, window_strides=(2, 2), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + b
        x = jnp.maximum(x, 0.0)
    feat = x.reshape(x.shape[0], -1)  # flatten spatial grid (B, h*w*C)
    h = jnp.maximum(feat @ params[idx] + params[idx + 1], 0.0)
    out = h @ params[idx + 2] + params[idx + 3]
    box = ref.jax_sigmoid(out[:, :4])
    conf = ref.jax_sigmoid(out[:, 4])
    return box, conf


def iou_cxcywh(a, b):
    """IoU of two (B, 4) center-format normalized box tensors."""
    ax1, ay1 = a[:, 0] - a[:, 2] / 2, a[:, 1] - a[:, 3] / 2
    ax2, ay2 = a[:, 0] + a[:, 2] / 2, a[:, 1] + a[:, 3] / 2
    bx1, by1 = b[:, 0] - b[:, 2] / 2, b[:, 1] - b[:, 3] / 2
    bx2, by2 = b[:, 0] + b[:, 2] / 2, b[:, 1] + b[:, 3] / 2
    ix = jnp.maximum(jnp.minimum(ax2, bx2) - jnp.maximum(ax1, bx1), 0.0)
    iy = jnp.maximum(jnp.minimum(ay2, by2) - jnp.maximum(ay1, by1), 0.0)
    inter = ix * iy
    union = a[:, 2] * a[:, 3] + b[:, 2] * b[:, 3] - inter
    return inter / jnp.maximum(union, 1e-9)


def make_tinydet_fwd(cfg: dict):
    """Artifact fn: (params…, images[B,H,W,3]) -> (box[B,4], conf[B])."""

    def fn(*args):
        params, images = list(args[:-1]), args[-1]
        return tinydet_forward(params, images, cfg)

    return fn


def make_tinydet_train_step(cfg: dict, frame: dict, lr: float = DET_LR):
    """Artifact fn: (params…, m…, v…, step, images, boxes[B,4])
    -> (params'…, m'…, v'…, loss). Box regression + IoU-target confidence."""
    n_tensors = len(detect_param_shapes(cfg, frame))

    def loss_fn(params, images, boxes):
        pred_box, conf = tinydet_forward(params, images, cfg)
        box_loss = jnp.mean(jnp.sum((pred_box - boxes) ** 2, axis=-1))
        iou = jax.lax.stop_gradient(iou_cxcywh(pred_box, boxes))
        conf_loss = jnp.mean((conf - iou) ** 2)
        return box_loss + 0.2 * conf_loss

    def fn(*args):
        params = list(args[:n_tensors])
        m = list(args[n_tensors:2 * n_tensors])
        v = list(args[2 * n_tensors:3 * n_tensors])
        step, images, boxes = args[3 * n_tensors:]
        loss, grads = jax.value_and_grad(loss_fn)(params, images, boxes)
        new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr)
        return tuple(new_p + new_m + new_v + [loss])

    return fn

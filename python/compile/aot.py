"""AOT lowering: JAX/Pallas models → HLO text artifacts + manifest.

Build-time only (`make artifacts`); never imported at runtime. For every
architecture in ``configs/arch.json`` this script lowers the decode and
train-step functions to **HLO text** and writes:

* ``artifacts/<name>.hlo.txt`` — one per artifact;
* ``artifacts/manifest.json`` — for each artifact, the exact positional
  argument list (name, shape, dtype) and output list the rust runtime
  must marshal.

HLO *text* (not ``.serialize()``): jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the `xla`
crate binds) rejects; the text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=True``; the rust side unwraps with
``to_tuple``.

Usage: ``cd python && python -m compile.aot [--out-dir ../artifacts] [--only substr]``
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def mlp_key(arch: dict) -> str:
    s = "s" if arch["sigmoid_out"] else "r"
    return f"l{arch['layers']}h{arch['hidden']}p{arch['posenc']}{s}"


class Builder:
    def __init__(self, out_dir: str, only: str | None):
        self.out_dir = out_dir
        self.only = only
        self.manifest: dict = {}
        self.n_lowered = 0

    def add(self, name: str, fn, args: list, outputs: list, kind: str, meta: dict):
        """args/outputs: list of (name, shape) in exact positional order."""
        if name in self.manifest:
            return  # deduplicated (identical arch shared across profiles)
        entry = {
            "file": f"{name}.hlo.txt",
            "kind": kind,
            "args": [[n, list(s)] for n, s in args],
            "outputs": [[n, list(s)] for n, s in outputs],
            "meta": meta,
        }
        self.manifest[name] = entry
        if self.only and self.only not in name:
            return
        path = os.path.join(self.out_dir, entry["file"])
        lowered = jax.jit(fn).lower(*[spec(s) for _, s in args])
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        self.n_lowered += 1
        print(f"  [{self.n_lowered}] {name}: {len(args)} args -> "
              f"{len(outputs)} outputs, {len(text) // 1024} KiB hlo")


def train_io(shapes, extra_inputs):
    """Positional signature of a fused-Adam train step: params, m, v,
    step, extra inputs; outputs: new params/m/v + loss."""
    args = [(n, s) for n, s in shapes]
    args += [(f"m_{n}", s) for n, s in shapes]
    args += [(f"v_{n}", s) for n, s in shapes]
    args.append(("step", ()))
    args += extra_inputs
    outs = [(f"new_{n}", s) for n, s in shapes]
    outs += [(f"new_m_{n}", s) for n, s in shapes]
    outs += [(f"new_v_{n}", s) for n, s in shapes]
    outs.append(("loss", ()))
    return args, outs


def build_all(cfg: dict, out_dir: str, only: str | None) -> Builder:
    b = Builder(out_dir, only)
    frame = cfg["frame"]
    n_full = frame["width"] * frame["height"]

    # ---- Rapid-INR family ------------------------------------------------
    mlp_cases: list[tuple[dict, int]] = []
    for prof in cfg["rapid"].values():
        mlp_cases.append((prof["background"], n_full))
        mlp_cases.append((prof["baseline"], n_full))
        for bin_ in prof["object_bins"]:
            mlp_cases.append((bin_["arch"], bin_["max_side"] ** 2))

    for arch, n in mlp_cases:
        key = mlp_key(arch)
        shapes = model.mlp_param_shapes(arch)
        meta = {"arch": arch, "n": n}
        b.add(
            f"rapid_decode_{key}_n{n}",
            model.make_rapid_decode(arch),
            [(nm, s) for nm, s in shapes] + [("coords", (n, 2))],
            [("rgb", (n, 3))],
            "rapid_decode",
            meta,
        )
        args, outs = train_io(
            shapes,
            [("coords", (n, 2)), ("targets", (n, 3)), ("mask", (n,))],
        )
        b.add(
            f"rapid_train_{key}_n{n}",
            model.make_rapid_train_step(arch),
            args,
            outs,
            "rapid_train",
            meta,
        )

    # ---- NeRV family -------------------------------------------------------
    bsz = cfg["nerv_decode_batch"]
    h, w = frame["height"], frame["width"]
    for name, arch in cfg["nerv"].items():
        if not isinstance(arch, dict) or "dim1" not in arch:
            continue
        shapes = model.nerv_param_shapes(arch)
        meta = {"arch": arch, "batch": bsz}
        b.add(
            f"nerv_decode_{name}_b{bsz}",
            model.make_nerv_decode(arch),
            [(nm, s) for nm, s in shapes] + [("t", (bsz,))],
            [("frames", (bsz, h, w, 3))],
            "nerv_decode",
            meta,
        )
        args, outs = train_io(
            shapes, [("t", (bsz,)), ("frames", (bsz, h, w, 3))]
        )
        b.add(
            f"nerv_train_{name}_b{bsz}", model.make_nerv_train_step(arch),
            args, outs, "nerv_train", meta,
        )

    # ---- TinyDet -----------------------------------------------------------
    det = cfg["detect"]
    db = det["batch"]
    shapes = model.detect_param_shapes(det, frame)
    meta = {"cfg": det}
    b.add(
        f"tinydet_fwd_b{db}",
        model.make_tinydet_fwd(det),
        [(nm, s) for nm, s in shapes] + [("images", (db, h, w, 3))],
        [("box", (db, 4)), ("conf", (db,))],
        "tinydet_fwd",
        meta,
    )
    args, outs = train_io(
        shapes, [("images", (db, h, w, 3)), ("boxes", (db, 4))]
    )
    b.add(
        f"tinydet_train_b{db}",
        model.make_tinydet_train_step(det, frame),
        args, outs, "tinydet_train", meta,
    )
    return b


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--config", default=None)
    ap.add_argument("--only", default=None, help="only lower artifacts whose name contains this substring (manifest still lists all)")
    args = ap.parse_args()

    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(here))
    out_dir = args.out_dir or os.path.join(root, "artifacts")
    cfg_path = args.config or os.path.join(root, "configs", "arch.json")
    os.makedirs(out_dir, exist_ok=True)
    with open(cfg_path) as f:
        cfg = json.load(f)

    print(f"lowering artifacts -> {out_dir}")
    b = build_all(cfg, out_dir, args.only)
    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(b.manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(b.manifest)} manifest entries ({b.n_lowered} lowered) "
          f"-> {manifest_path}")


if __name__ == "__main__":
    sys.exit(main())

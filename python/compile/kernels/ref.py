"""Pure-jnp reference implementations (correctness oracles).

Every Pallas kernel in this package is checked against these functions by
``python/tests`` (pytest + hypothesis). They are also the building blocks
of the *training* paths in ``model.py``: encoding an INR happens on the fog
node via jnp fwd/bwd (autodiff through ``pallas_call`` is not supported on
the CPU interpret path), while the *decode* hot path — what edge devices
run per training batch — goes through the fused Pallas kernels.
"""

from __future__ import annotations

import jax.numpy as jnp


def posenc(x: jnp.ndarray, freqs: int) -> jnp.ndarray:
    """NeRF-style positional encoding.

    x: (N, D) coordinates in [0, 1]. Output: (N, D + 2*D*freqs) —
    ``[x, sin(2^k pi x), cos(2^k pi x) for k < freqs]``.
    """
    parts = [x]
    for k in range(freqs):
        w = (2.0 ** k) * jnp.pi
        parts.append(jnp.sin(w * x))
        parts.append(jnp.cos(w * x))
    return jnp.concatenate(parts, axis=-1)


def posenc_dim(in_dim: int, freqs: int) -> int:
    return in_dim + 2 * in_dim * freqs


def jax_sigmoid(x):
    # Stable sigmoid without jax.nn import on the hot compile path.
    return 0.5 * (jnp.tanh(0.5 * x) + 1.0)


def mlp_decode(params, coords, freqs: int, sigmoid_out: bool):
    """Coordinate-MLP forward pass (Rapid-INR family).

    params: flat list [w0, b0, w1, b1, ...]; coords: (N, 2) in [0, 1].
    Hidden activation: sine (SIREN-style); head: sigmoid for RGB nets,
    linear for residual (object) nets. Returns (N, 3).
    """
    h = posenc(coords, freqs)
    n_layers = len(params) // 2
    for l in range(n_layers):
        w, b = params[2 * l], params[2 * l + 1]
        h = h @ w + b
        if l < n_layers - 1:
            h = jnp.sin(h)
    return jax_sigmoid(h) if sigmoid_out else h


def matmul_bias(x, w, b, activation: str = "none"):
    """Reference for the generic Pallas matmul kernel: act(x @ w + b)."""
    y = x @ w + b
    if activation == "sin":
        return jnp.sin(y)
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "sigmoid":
        return jax_sigmoid(y)
    if activation == "none":
        return y
    raise ValueError(f"unknown activation {activation!r}")


def frame_grid(width: int, height: int) -> jnp.ndarray:
    """Pixel-center coordinates of a full frame, row-major (N, 2) in [0,1].

    Order matches the rust image layout: index i = y * width + x,
    coords[i] = [x_norm, y_norm].
    """
    ys, xs = jnp.meshgrid(
        (jnp.arange(height) + 0.5) / height,
        (jnp.arange(width) + 0.5) / width,
        indexing="ij",
    )
    return jnp.stack([xs.reshape(-1), ys.reshape(-1)], axis=-1)


def patch_grid(side: int) -> jnp.ndarray:
    """Local coordinates of a side×side object patch (row-major, [0,1])."""
    return frame_grid(side, side)


def pixel_shuffle(x, r: int):
    """Depth-to-space: (B, H, W, C*r^2) -> (B, H*r, W*r, C)."""
    b, h, w, c = x.shape
    assert c % (r * r) == 0
    cout = c // (r * r)
    x = x.reshape(b, h, w, r, r, cout)
    x = x.transpose(0, 1, 3, 2, 4, 5)  # b, h, r, w, r, cout
    return x.reshape(b, h * r, w * r, cout)


def nerv_decode(params, t, arch):
    """NeRV-style video INR forward (reference).

    params: flat list matching ``NervArch.param_shapes()`` order:
      [stem_w1, stem_b1, stem_w2, stem_b2,
       conv0_w, conv0_b, ..., head_w, head_b]
    t: (B,) normalized frame indices in [0, 1].
    arch: dict with posenc, dim1, c0, channels, h0, w0.
    Returns frames (B, H, W, 3) in [0, 1].
    """
    import jax

    pe = posenc(t[:, None], arch["posenc"])  # (B, 1+2F)
    h = jnp.sin(pe @ params[0] + params[1])  # (B, dim1)
    h = h @ params[2] + params[3]  # (B, dim2)
    b = t.shape[0]
    c0, h0, w0 = arch["c0"], arch["h0"], arch["w0"]
    x = h.reshape(b, h0, w0, c0)  # NHWC
    idx = 4
    for cout in arch["channels"]:
        w, bias = params[idx], params[idx + 1]
        idx += 2
        x = jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + bias
        x = pixel_shuffle(x, 2)  # (B, 2h, 2w, cout)
        x = jnp.maximum(x, 0.0)  # NeRV uses GELU; ReLU is the cheap analogue
        assert x.shape[-1] == cout, (x.shape, cout)
    w, bias = params[idx], params[idx + 1]
    x = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + bias
    return jax_sigmoid(x)

"""Layer-1 Pallas kernels: the INR decode hot path.

``fused_mlp_decode`` runs the entire coordinate-MLP (positional encoding +
all linear layers + activations) in ONE Pallas kernel, tiled over pixel
blocks. This is the operation edge devices execute for every image of
every training batch (paper §3.2), so it is the hot spot the paper
accelerates on-device.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
decoder launches per-layer GEMMs over warps; on TPU we instead keep the
*whole* (tiny, by design) weight stack resident in VMEM and stream only
coordinates/outputs through HBM→VMEM with a `BlockSpec` over the pixel
axis — no inter-layer HBM round-trips. Block size `BLOCK_N` trades VMEM
footprint (BLOCK_N × max(posenc_dim, hidden) activations) against grid
overhead; 512 keeps the largest config ≪ 1 MB of VMEM.

All kernels use ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO so the AOT
artifacts run anywhere (correctness path). TPU perf is estimated
analytically in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Pixel-block tile. One grid step covers the whole 12288-pixel frame:
# measured fastest on CPU-interpret (EXPERIMENTS.md §Perf L1); still <2 MB
# VMEM per step on real TPU for the largest config.
BLOCK_N = 2048


def _decode_kernel(*refs, n_layers: int, freqs: int, sigmoid_out: bool):
    """Kernel body: refs = (coords, w0, b0, ..., w{L-1}, b{L-1}, out)."""
    coords_ref = refs[0]
    out_ref = refs[-1]
    wrefs = refs[1:-1]
    x = coords_ref[...]  # (BN, 2)
    # Positional encoding, unrolled (static freqs): [x, sin(2^k pi x), cos]
    parts = [x]
    for k in range(freqs):
        w = (2.0 ** k) * jnp.pi
        parts.append(jnp.sin(w * x))
        parts.append(jnp.cos(w * x))
    h = jnp.concatenate(parts, axis=-1)
    # Fused MLP: every layer is a (BN, d_in) @ (d_in, d_out) MXU matmul with
    # the sine VPU activation in between; weights stay resident.
    for l in range(n_layers):
        w = wrefs[2 * l][...]
        b = wrefs[2 * l + 1][...]
        h = jnp.dot(h, w, preferred_element_type=jnp.float32) + b
        if l < n_layers - 1:
            h = jnp.sin(h)
    out_ref[...] = ref.jax_sigmoid(h) if sigmoid_out else h


def fused_mlp_decode(params, coords, freqs: int, sigmoid_out: bool,
                     block_n: int = BLOCK_N):
    """Decode RGB (or residual) values for (N, 2) coords via one fused
    Pallas kernel. N is padded to a multiple of ``block_n`` internally;
    output is sliced back to N rows. Matches ``ref.mlp_decode``.
    """
    n = coords.shape[0]
    n_layers = len(params) // 2
    bn = min(block_n, _ceil_to(n, 8))
    n_pad = _ceil_to(n, bn)
    if n_pad != n:
        coords = jnp.pad(coords, ((0, n_pad - n), (0, 0)))
    grid = (n_pad // bn,)

    in_specs = [pl.BlockSpec((bn, 2), lambda i: (i, 0))]
    # Weights: whole-array blocks, same for every grid step (VMEM-resident).
    for p in params:
        if p.ndim == 2:
            in_specs.append(pl.BlockSpec(p.shape, lambda i: (0, 0)))
        else:
            in_specs.append(pl.BlockSpec(p.shape, lambda i: (0,)))

    out = pl.pallas_call(
        functools.partial(
            _decode_kernel, n_layers=n_layers, freqs=freqs,
            sigmoid_out=sigmoid_out,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 3), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(coords, *params)
    return out[:n]


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if activation == "sin":
        y = jnp.sin(y)
    elif activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "sigmoid":
        y = ref.jax_sigmoid(y)
    o_ref[...] = y


def matmul_bias(x, w, b, activation: str = "none", block_m: int = 128):
    """Generic Pallas `act(x @ w + b)` tiled over rows of x.

    Used for the NeRV stem (the (B, dim1) @ (dim1, dim2) expansion — NeRV's
    single largest matmul). Weights are whole-array VMEM-resident; rows of
    `x` stream through the grid. Matches ``ref.matmul_bias``.
    """
    m, _k = x.shape
    bm = min(block_m, _ceil_to(m, 8))
    m_pad = _ceil_to(m, bm)
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, activation=activation),
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((bm, x.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, w.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, w.shape[1]), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:m]


def _ceil_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def vmem_estimate_bytes(param_shapes, block_n: int, freqs: int) -> int:
    """Estimated VMEM footprint of one fused-decode grid step: resident
    weights + coordinate tile + widest activation tile (double-buffered
    coords/out). Used by DESIGN.md / EXPERIMENTS.md §Perf TPU estimates."""
    weight = sum(int(jnp.prod(jnp.array(s))) for s in param_shapes) * 4
    widest = max(
        ref.posenc_dim(2, freqs),
        max(int(s[-1]) for s in param_shapes),
    )
    act = block_n * widest * 4
    io = 2 * (block_n * 2 * 4 + block_n * 3 * 4)  # double-buffered in/out
    return weight + act + io
